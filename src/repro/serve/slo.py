"""SLO layer — Poisson arrival driving and latency accounting.

The engine's batch interface (`submit` everything, one `run()`) answers
"jobs per second" but not the question an always-on hyperopt service is
judged on: *what latency does the p99 tenant see when jobs arrive at
random?*  This module closes that gap without touching the engine's
scheduling loop:

* `poisson_arrivals` draws a Poisson arrival process (i.i.d.
  exponential inter-arrival gaps, seeded, reproducible);
* `drive_poisson` replays job specs against a live `ServeEngine` on
  that schedule — due jobs are submitted the moment the driver observes
  their arrival time, and the engine runs in waves whenever its queue
  is non-empty (jobs landing while a wave is in flight queue up and are
  submitted at the next wave boundary, exactly how a service front-end
  batches admissions);
* `job_latencies` pairs the **already-emitted** submit/retire lifecycle
  instants from the tracer by `job_id` — no second bookkeeping channel,
  the latency a tenant experiences is literally the distance between
  two trace events;
* `observe_latencies` publishes the distribution into the metrics
  registry: a `serve_job_latency_seconds` histogram plus p50/p99
  gauges, next to the queue-depth / in-flight gauges the engine itself
  maintains.

`benchmarks/bench_serve.py` turns this into the `serve/slo_poisson`
row (p50/p99 under a Poisson stream, not just batch jobs/s), and
`benchmarks/report.py --gate` bounds the p99 with the same slower-only
tolerance as wall clock.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Sequence

import numpy as np

from repro import obs

#: Quantiles every report publishes (p50 = median, p99 = SLO tail).
SLO_QUANTILES = (0.5, 0.99)


def poisson_arrivals(n: int, rate_hz: float, seed: int = 0) -> np.ndarray:
    """Arrival offsets (seconds from the stream start) of `n` jobs from
    a Poisson process with intensity `rate_hz`: cumulative sums of
    i.i.d. Exp(rate) inter-arrival gaps, nondecreasing, reproducible
    per seed."""
    if n < 0:
        raise ValueError(f"need a non-negative job count (got {n})")
    if not rate_hz > 0:
        raise ValueError(
            f"rate_hz must be a positive arrival intensity "
            f"(got {rate_hz})")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=1.0 / float(rate_hz), size=int(n))
    return np.cumsum(gaps)


def job_latencies(events, *, start: str = "submit",
                  end: str = "retire",
                  since: float | None = None) -> dict[str, float]:
    """Pair lifecycle instants by `args["job_id"]` → latency seconds.

    `events` is a `Tracer` or a raw SpanEvent list.  The first `start`
    instant and the first `end` instant per job id win (job ids are
    unique per engine run); jobs with no `end` yet are simply absent —
    the caller decides whether in-flight jobs matter.  `since` (tracer
    µs, compare `Tracer.now_us`) ignores instants recorded before it —
    how a long-lived service's driver scopes one measurement window out
    of an always-on tracer without clearing it."""
    if hasattr(events, "events"):
        events = events.events()
    starts: dict[str, float] = {}
    ends: dict[str, float] = {}
    for ev in events:
        if ev.dur_us is not None or "job_id" not in ev.args:
            continue
        if since is not None and ev.ts_us < since:
            continue
        jid = ev.args["job_id"]
        if ev.name == start and jid not in starts:
            starts[jid] = ev.ts_us
        elif ev.name == end and jid not in ends:
            ends[jid] = ev.ts_us
    return {jid: (ends[jid] - starts[jid]) * 1e-6
            for jid in ends if jid in starts}


def latency_quantiles(latencies_s,
                      qs: Sequence[float] = SLO_QUANTILES
                      ) -> dict[float, float]:
    """{q: quantile seconds} with numpy's default linear interpolation
    (deterministic, exact against hand-computed schedules in the
    tests).  Raises on an empty sample — a service with zero retired
    jobs has no latency, and silently reporting 0.0 would read as a
    perfect SLO."""
    vals = np.asarray(list(latencies_s), dtype=np.float64)
    if vals.size == 0:
        raise ValueError(
            "no completed jobs to take latency quantiles over")
    return {float(q): float(np.quantile(vals, q)) for q in qs}


def observe_latencies(latencies_s, reg=None, **labels) -> dict[float, float]:
    """Publish the latency distribution into `reg` (default registry):
    every sample into the `serve_job_latency_seconds` histogram and the
    `SLO_QUANTILES` into `serve_job_latency_p{50,99}_seconds` gauges.
    Returns the quantile dict."""
    reg = reg or obs.registry()
    vals = [float(v) for v in latencies_s]
    hist = reg.histogram(
        "serve_job_latency_seconds",
        "submit→retire latency of completed serve jobs")
    child = hist.labels(**labels)
    for v in vals:
        child.observe(v)
    quants = latency_quantiles(vals)
    for q, v in quants.items():
        pct = int(round(q * 100))
        reg.gauge(
            f"serve_job_latency_p{pct}_seconds",
            f"p{pct} submit→retire latency of completed serve jobs"
        ).labels(**labels).set(v)
    return quants


@dataclasses.dataclass
class SLOReport:
    """What one Poisson-driven engine session measured."""
    jobs: int                     # specs offered to the stream
    retired: int                  # jobs that produced a retire instant
    wall_s: float                 # driver wall clock, first submit→drain
    rate_hz: float                # offered arrival intensity
    waves: int                    # engine.run() invocations
    peak_queue_depth: int         # max queued jobs at a wave boundary
    latencies_s: np.ndarray       # per-retired-job submit→retire seconds
    p50_s: float
    p99_s: float
    throughput_jobs_s: float      # retired / wall
    results: list                 # JobResults in completion-wave order

    def as_record(self) -> dict:
        """JSON-safe dict for `obs.MetricsJsonlWriter.write_record` —
        the whole report minus `results` (JobResults hold device
        arrays; the metrics sink wants numbers), latencies as a plain
        list."""
        rec = {f.name: getattr(self, f.name)
               for f in dataclasses.fields(self)
               if f.name != "results"}
        rec["latencies_s"] = [float(v) for v in self.latencies_s]
        rec["kind"] = "slo_report"
        return rec


def drive_poisson(engine, specs: Iterable, rate_hz: float,
                  seed: int = 0, reg=None, **labels) -> SLOReport:
    """Offer `specs` to `engine` on a Poisson arrival schedule and
    report tail latency.

    Runs inside `obs.tracing()` (enabling the default tracer for the
    duration) so the engine's own submit/retire instants exist to be
    paired; latency is computed *only* from those instants.  The driver
    loop alternates between submitting every due spec and draining the
    queue with `engine.run()` — a wave in flight delays the next
    admissions to the wave boundary, and that queueing delay is part of
    the measured latency, as it would be for a real tenant."""
    specs = list(specs)
    arrivals = poisson_arrivals(len(specs), rate_hz, seed)
    results: list = []
    submitted: list[str] = []
    waves = 0
    peak_queue = 0
    with obs.tracing() as tr:
        t0 = time.perf_counter()
        i = 0
        while i < len(specs) or engine._queue:
            now = time.perf_counter() - t0
            while i < len(specs) and arrivals[i] <= now:
                ids = engine.submit(specs[i])
                for jid in ids:
                    tr.instant("arrival", cat="serve.slo", track="load",
                               job_id=jid,
                               scheduled_s=float(arrivals[i]))
                submitted.extend(ids)
                i += 1
            peak_queue = max(peak_queue, len(engine._queue))
            if engine._queue:
                results.extend(engine.run())
                waves += 1
            elif i < len(specs):
                time.sleep(min(max(arrivals[i] - now, 0.0), 0.05))
        wall = time.perf_counter() - t0
        lat = job_latencies(tr.events())
    vals = np.array([lat[jid] for jid in submitted if jid in lat])
    quants = observe_latencies(vals, reg=reg, **labels)
    reg = reg or obs.registry()
    reg.gauge(
        "serve_peak_queue_depth",
        "max queued jobs observed at a Poisson wave boundary"
    ).labels(**labels).set(float(peak_queue))
    return SLOReport(
        jobs=len(specs), retired=int(vals.size), wall_s=wall,
        rate_hz=float(rate_hz), waves=waves,
        peak_queue_depth=peak_queue, latencies_s=vals,
        p50_s=quants[0.5], p99_s=quants[0.99],
        throughput_jobs_s=float(vals.size) / max(wall, 1e-9),
        results=results)


def drive_poisson_async(loop, specs: Iterable, rate_hz: float,
                        seed: int = 0, reg=None,
                        **labels) -> SLOReport:
    """`drive_poisson` against an `admission.AdmissionLoop`: the SAME
    seeded arrival schedule, but jobs are submitted to the always-on
    loop the moment they arrive and join buckets at the next chunk
    boundary — no wave barrier, so a job's latency no longer includes
    waiting out every earlier arrival's full run.  `waves` is 0 by
    construction; the before/after against `drive_poisson` on the same
    schedule is the admission loop's headline number."""
    specs = list(specs)
    arrivals = poisson_arrivals(len(specs), rate_hz, seed)
    submitted: list[str] = []
    peak_queue = 0
    own_thread = not loop.running
    with obs.tracing() as tr:
        since = tr.now_us()
        if own_thread:
            loop.start()
        try:
            t0 = time.perf_counter()
            for i, spec in enumerate(specs):
                wait = arrivals[i] - (time.perf_counter() - t0)
                if wait > 0:
                    time.sleep(wait)
                ids = loop.submit(spec)
                for jid in ids:
                    tr.instant("arrival", cat="serve.slo", track="load",
                               job_id=jid,
                               scheduled_s=float(arrivals[i]))
                submitted.extend(ids)
                peak_queue = max(peak_queue, len(loop.queue))
            results = [loop.result(jid) for jid in submitted]
            wall = time.perf_counter() - t0
        finally:
            if own_thread:
                loop.stop()
        lat = job_latencies(tr.events(), since=since)
    vals = np.array([lat[jid] for jid in submitted if jid in lat])
    quants = observe_latencies(vals, reg=reg, **labels)
    reg = reg or obs.registry()
    reg.gauge(
        "serve_peak_queue_depth",
        "max queued jobs observed at a Poisson wave boundary"
    ).labels(**labels).set(float(peak_queue))
    return SLOReport(
        jobs=len(specs), retired=int(vals.size), wall_s=wall,
        rate_hz=float(rate_hz), waves=0,
        peak_queue_depth=peak_queue, latencies_s=vals,
        p50_s=quants[0.5], p99_s=quants[0.99],
        throughput_jobs_s=float(vals.size) / max(wall, 1e-9),
        results=results)
