from .optimizers import (Optimizer, adamw, sgd, apply_updates,
                         clip_by_global_norm, global_norm,
                         cosine_schedule, constant_schedule,
                         inverse_sqrt_schedule, power_schedule)
