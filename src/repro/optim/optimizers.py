"""Optimizers built from scratch (no optax): SGD(+momentum), AdamW,
schedules, global-norm clipping.  Interface mirrors the usual pattern:

    opt = adamw(lr=..., ...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Optimizer state trees mirror the parameter tree, so sharding params
shards the state identically (ZeRO-style sharding is a spec choice, not
a code change).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Params = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Params], tuple[Params, Any]]


def apply_updates(params: Params, updates: Params) -> Params:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype),
                        params, updates)


def global_norm(tree: Params) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Params, max_norm: float) -> Params:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr \
            * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return sched


def power_schedule(base: float, power: float,
                   offset: float = 1.0) -> Schedule:
    """base · ((step + offset)/offset)^power.

    Negative powers give the decaying step-size sequences of the
    decentralized-bilevel theory (αₖ, βₖ ∝ k^{-p}); positive powers
    give growing sequences (the penalty coefficient γₖ of the paper's
    corollaries grows as alpha shrinks).  `offset` shifts the origin so
    the schedule starts at exactly `base` and avoids the k=0 pole."""
    if offset <= 0:
        raise ValueError(f"power_schedule offset must be > 0 "
                         f"(got {offset})")

    def sched(step):
        t = (step.astype(jnp.float32) + offset) / offset
        return jnp.asarray(base, jnp.float32) * t ** power
    return sched


def inverse_sqrt_schedule(base: float, offset: float = 1.0) -> Schedule:
    """base / √((step + offset)/offset) — the classic O(1/√k) decay
    (Chen, Huang & Ma 2022 run DAGM-class methods with exactly this
    family)."""
    return power_schedule(base, -0.5, offset)


# ---------------------------------------------------------------------------
# SGD (+ momentum)
# ---------------------------------------------------------------------------

class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Params | None


def sgd(lr: float | Schedule, momentum: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        mom = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                           params) if momentum else None
        return SGDState(jnp.zeros((), jnp.int32), mom)

    def update(grads, state, params):
        lr_t = sched(state.step)
        if momentum:
            mom = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state.momentum, grads)
            upd = jax.tree.map(lambda m: -lr_t * m, mom)
        else:
            mom = None
            upd = jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32),
                               grads)
        return upd, SGDState(state.step + 1, mom)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Params
    nu: Params


def adamw(lr: float | Schedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = sched(state.step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1)
                          * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                          * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            return -lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                            + weight_decay * p.astype(jnp.float32))

        return jax.tree.map(upd, mu, nu, params), AdamWState(step, mu, nu)

    return Optimizer(init, update)
