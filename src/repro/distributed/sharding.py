"""Logical-axis sharding rules → NamedSharding / PartitionSpec.

Model code annotates parameters and activations with *logical* axis names
("batch", "vocab", "ffn", "heads", ...).  A `ShardingRules` object maps
those to mesh axes for a given (ArchConfig, mesh) pair, implementing the
scheme in DESIGN.md §5:

  batch   → ("pod", "data")      (or ("data",) single-pod)
  vocab   → "model"              (vocab padded to /256 so it divides)
  ffn     → "model"              (d_ff, mamba d_inner, rwkv dims)
  heads   → "model" iff num_heads % model_size == 0 else replicated
  kv_heads→ "model" iff num_kv_heads % model_size == 0 else replicated
  experts → None (TP-inside-expert default) or "model" (expert-parallel
            opt-in layout, used in EXPERIMENTS §Perf)
  seq     → None by default; "data" for the sequence-sharded long_500k
            decode cache (batch=1 cannot shard over data)

Rules are installed in a module-level context (`use_rules`); `shard(x,
*logical_axes)` is a no-op when no rules are installed, so single-device
CPU tests run the exact same model code.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

_state = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    table: dict  # logical name -> mesh axis name | tuple | None

    def resolve(self, *logical: str | None) -> P:
        return P(*[self.table.get(a) if a is not None else None
                   for a in logical])

    def named(self, *logical: str | None) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(*logical))


def make_rules(cfg: ArchConfig, mesh: Mesh, *,
               expert_parallel: bool = False,
               seq_shard_cache: bool = False,
               fsdp: bool = True) -> ShardingRules:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = "model" if "model" in axes else None
    msize = axes.get("model", 1)
    batch = tuple(a for a in ("pod", "data") if a in axes) or None

    def if_div(k: int):
        return model if (model and k and k % msize == 0) else None

    kv = if_div(cfg.num_kv_heads)
    # A single PartitionSpec may use each mesh axis once: when KV heads
    # already shard over `model` (e.g. zamba2 kv=32), the cache sequence
    # axis must stay replicated; seq-sharding is the fallback for
    # GQA/MQA archs whose kv count does not divide the model axis.
    table = {
        "batch": batch,
        "vocab": model,
        "ffn": model,
        "embed": None,
        "heads": if_div(cfg.num_heads),
        "kv_heads": kv,
        "rwkv_heads": if_div(cfg.d_model // max(cfg.rwkv_head_size, 1))
        if cfg.attn_free else None,
        "experts": (model if expert_parallel else None),
        "cache_seq": (model if seq_shard_cache and kv is None else None),
        "fsdp": ("data" if fsdp and "data" in axes else None),
        "frames": None,
    }
    return ShardingRules(mesh=mesh, table=table)


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def current_rules() -> ShardingRules | None:
    return getattr(_state, "rules", None)


def shard(x, *logical: str | None):
    """with_sharding_constraint under the installed rules (no-op if none).

    Pass one logical axis name (or None) per array dimension."""
    rules = current_rules()
    if rules is None:
        return x
    if x.ndim != len(logical):
        raise ValueError(f"rank {x.ndim} vs {len(logical)} logical axes")
    return jax.lax.with_sharding_constraint(x, rules.named(*logical))


def tree_param_sharding(param_axes, rules: ShardingRules):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda axes: rules.named(*axes), param_axes,
        is_leaf=lambda t: isinstance(t, tuple))
