"""Decentralized mixing as TPU collectives.

The paper's gossip step — each agent averages its state with its graph
neighbors through the mixing matrix W — maps onto `lax.ppermute` for
circulant (shift-invariant) graphs: W·y at agent i is a weighted sum of
y from agents i±o for the offsets o of the graph.  ppermute is the
native contention-free ICI pattern, and *no all-reduce appears anywhere
in the optimization path* (the paper's communication-efficiency claim,
made structural).

Works on arbitrary pytrees (model-parameter states).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.topology import make_network, Network


@dataclasses.dataclass(frozen=True)
class RingWeights:
    """Shift-invariant mixing weights: w_self + {offset: weight}."""
    n: int
    w_self: float
    offsets: dict  # offset (±o) -> weight

    @classmethod
    def metropolis_ring(cls, n: int) -> "RingWeights":
        # ring: deg 2 everywhere -> w_edge = 1/3, w_self = 1/3
        return cls(n=n, w_self=1.0 / 3.0,
                   offsets={+1: 1.0 / 3.0, -1: 1.0 / 3.0})

    @classmethod
    def metropolis_circulant(cls, n: int, hops: int) -> "RingWeights":
        """2·hops-regular circulant with Metropolis weights."""
        deg = 2 * hops
        w = 1.0 / (1.0 + deg)
        offs = {}
        for o in range(1, hops + 1):
            offs[+o] = w
            offs[-o] = w
        return cls(n=n, w_self=1.0 - deg * w, offsets=offs)

    def to_network(self) -> Network:
        """Dense-W Network equivalent (reference-tier comparisons)."""
        hops = max(abs(o) for o in self.offsets)
        return make_network("circulant", self.n,
                            offsets=tuple(range(1, hops + 1)))


def ppermute_shift(x, axis_name: str, offset: int, n: int):
    """Receive the value held by agent (i - offset) mod n."""
    perm = [(j, (j + offset) % n) for j in range(n)]
    return lax.ppermute(x, axis_name, perm)


def ring_mix(tree, axis_name: str, w: RingWeights, comm_dtype=None):
    """(W ⊗ I) applied to per-agent pytree state via neighbor exchange.

    `comm_dtype` (e.g. jnp.bfloat16) quantizes only the *communicated*
    copies; the local term and the accumulation stay in the leaf dtype.
    This is the beyond-paper compressed-gossip variant (EXPERIMENTS
    §Perf) — cf. Koloskova et al. [34] on compressed decentralized SGD.
    """
    def mix_leaf(x):
        out = w.w_self * x
        if comm_dtype is None:
            send = x
        else:
            # optimization_barrier pins the down-cast *before* the
            # ppermute: XLA otherwise commutes convert past the permute
            # (elementwise ∘ data-movement) and the wire stays f32 —
            # measured in EXPERIMENTS §Perf-3.
            send = lax.optimization_barrier(x.astype(comm_dtype))
        for offset, weight in w.offsets.items():
            recv = ppermute_shift(send, axis_name, offset, w.n)
            out = out + weight * recv.astype(x.dtype)
        return out
    return jax.tree.map(mix_leaf, tree)


def ring_laplacian(tree, axis_name: str, w: RingWeights, comm_dtype=None):
    """((I − W) ⊗ I) x."""
    mixed = ring_mix(tree, axis_name, w, comm_dtype)
    return jax.tree.map(lambda a, b: a - b, tree, mixed)


# ---- compressed gossip channel (repro.comm) ----

def ring_mix_c(tree, axis_name: str, w: RingWeights, policy, st):
    """`ring_mix` through a `repro.comm` channel -> (mixed, state).

    Each agent transmits the compressed payload of its pytree state —
    with CHOCO-style error feedback the innovation against the replica
    `st.hat` its neighbors hold — while the self-weight term w_self·x
    stays exact (it never crosses the wire).  "identity" delegates to
    the plain path bit-for-bit; "bf16" keeps the optimization_barrier
    down-cast so the wire really is 2 bytes/float; value-simulated
    compressors (int8/int4/top_k/rand_k) quantize the payload values
    before the ppermute — the packed wire is the ROADMAP fused
    quantize+gather Pallas kernel.  `st` is a `ChannelState` whose
    `hat` mirrors the tree structure (see `sharded_channel_init`)."""
    from repro.comm import compressed_payload_local
    if policy.is_identity:
        return ring_mix(tree, axis_name, w), st.bump()
    if policy.compressor.name == "bf16" and not policy.ef:
        return ring_mix(tree, axis_name, w, jnp.bfloat16), st.bump()

    leaves, treedef = jax.tree.flatten(tree)
    if policy.stochastic:
        key, *subs = jax.random.split(st.key, len(leaves) + 1)
    else:
        key, subs = st.key, [None] * len(leaves)
    hats = treedef.flatten_up_to(st.hat) if policy.ef \
        else [None] * len(leaves)
    payloads, new_hats = [], []
    for leaf, hat, sub in zip(leaves, hats, subs):
        p, h = compressed_payload_local(policy, leaf, hat, sub)
        payloads.append(p)
        new_hats.append(h)

    def mix_leaf(x, xh):
        out = w.w_self * x
        send = lax.optimization_barrier(xh)
        for offset, weight in w.offsets.items():
            out = out + weight * ppermute_shift(send, axis_name, offset,
                                                w.n)
        return out
    mixed = treedef.unflatten([mix_leaf(x, xh) for x, xh
                               in zip(leaves, payloads)])
    hat = treedef.unflatten(new_hats) if policy.ef else st.hat
    return mixed, dataclasses.replace(st, hat=hat, key=key,
                                      sends=st.sends + 1)


def ring_laplacian_c(tree, axis_name: str, w: RingWeights, policy, st):
    """((I − W) ⊗ I) x through the compressed channel."""
    mixed, st = ring_mix_c(tree, axis_name, w, policy, st)
    return jax.tree.map(lambda a, b: a - b, tree, mixed), st


# ---- pytree vector-space helpers used by the sharded DAGM ----

def tadd(a, b):
    return jax.tree.map(jnp.add, a, b)


def tsub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tscale(c, a):
    return jax.tree.map(lambda x: c * x, a)


def taxpy(c, a, b):
    """b + c * a."""
    return jax.tree.map(lambda x, y: y + c * x, a, b)


def tdot(a, b):
    return sum(jnp.vdot(x, y) for x, y
               in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def tnorm(a):
    return jnp.sqrt(tdot(a, a).real)
