"""Pod-scale DAGM: the paper's Algorithm 2 as a shard_map program.

Agents = slices of the mesh "data" axis (and "pod" × "data" multi-pod).
Each agent holds a *pytree* copy of the inner variable y (e.g. model
parameters) and the outer variable x (e.g. loss weights / regularizers),
plus its local data shard.  All cross-agent communication is
`lax.ppermute` neighbor exchange over a circulant graph (see
collectives.ring_mix) — vectors only, never matrices, exactly the
paper's communication pattern.

The inner Hessian-vector products use jvp-of-grad (matrix-free), and
DIHGP uses the scalar-preconditioned splitting of repro.core.dihgp
(D̃ = (β·c + 2(1−w_ii))I), so nothing larger than a parameter pytree is
ever materialized or communicated.

`dagm_sharded_step` is written against per-agent local views (it runs
*inside* shard_map); `make_sharded_dagm` wires it into a jitted global
step for a given mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import shard_map
from .collectives import (RingWeights, ring_laplacian, ring_mix, taxpy,
                          tdot, tnorm, tscale, tsub, tadd)

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ShardedDAGMConfig:
    alpha: float = 1e-2
    beta: float = 1e-2
    M: int = 5                 # inner DGD steps per outer step
    U: int = 3                 # Neumann order
    curvature: float = 4.0     # c ≥ λmax(∇²_y g_i) bound (scalar precond)
    axis: str | tuple = "data"  # agent mesh axis; a tuple (e.g.
    #                             ("pod", "data")) rings the agents over
    #                             the flattened product of those axes —
    #                             the cross-pod ring of the multi-pod
    #                             DAGM dry-run
    comm_dtype: str = "f32"    # "bf16" = compressed gossip (§Perf
    #                            variant) — same "f32" | "bf16"
    #                            vocabulary as the reference tier's
    #                            DAGMConfig.mixing_dtype, resolved by the
    #                            shared topology.resolve_mixing_dtype
    mix_every: int = 1         # j > 1: gossip only every j-th inner step
    #                            (local-updates variant, cf. FedNest [77];
    #                            §Perf — cuts inner comm by ~j)
    unroll_loops: bool = False  # Python-unroll the M/U loops so AOT
    #                             cost_analysis counts every iteration
    #                             (fori_loop bodies are counted once);
    #                             used by the dagm_dryrun accounting

    @property
    def comm_jnp_dtype(self):
        from repro.topology import resolve_mixing_dtype
        return resolve_mixing_dtype(self.comm_dtype)


def dagm_local_round(g_fn: Callable, f_fn: Callable,
                     cfg: ShardedDAGMConfig, w: RingWeights,
                     x: Pytree, y: Pytree, batch: Pytree):
    """One DAGM outer round from a single agent's perspective.

    g_fn(x, y, batch) -> scalar local inner loss  (strongly-convex-ish)
    f_fn(x, y, batch) -> scalar local outer loss
    Must be called inside shard_map over cfg.axis.
    Returns (x⁺, y⁺, metrics).
    """
    axis = cfg.axis
    beta, alpha = cfg.beta, cfg.alpha

    grad_y_g = jax.grad(g_fn, argnums=1)
    grad_x_f = jax.grad(f_fn, argnums=0)
    grad_y_f = jax.grad(f_fn, argnums=1)

    cd = cfg.comm_jnp_dtype

    # ---- inner loop: y ← W y − β ∇_y g  (Eq. 15/16), M rounds ----
    def inner(t, yy):
        if cfg.unroll_loops:
            do_mix = (int(t) % cfg.mix_every) == cfg.mix_every - 1
            mixed = ring_mix(yy, axis, w, cd) if do_mix else yy
        elif cfg.mix_every > 1:
            mixed = jax.lax.cond(
                t % cfg.mix_every == cfg.mix_every - 1,
                lambda z: ring_mix(z, axis, w, cd), lambda z: z, yy)
        else:
            mixed = ring_mix(yy, axis, w, cd)
        return taxpy(-beta, grad_y_g(x, yy, batch), mixed)
    if cfg.unroll_loops:
        for t in range(cfg.M):
            y = inner(t, y)
    else:
        y = jax.lax.fori_loop(0, cfg.M, inner, y)

    # ---- DIHGP (Alg. 1, scalar-preconditioned, matrix-free) ----
    def hvp(v):
        return jax.jvp(lambda yy: grad_y_g(x, yy, batch), (y,), (v,))[1]

    d_scalar = beta * cfg.curvature + 2.0 * (1.0 - w.w_self)

    def H_apply(hh):
        lap = ring_laplacian(hh, axis, w, cd)
        return taxpy(beta, hvp(hh), lap)

    p = grad_y_f(x, y, batch)
    h = tscale(-1.0 / d_scalar, p)
    def dihgp_iter(_, hh):
        bh = tsub(tscale(d_scalar, hh), H_apply(hh))   # B̃ h
        return tscale(1.0 / d_scalar, tsub(bh, p))
    if cfg.unroll_loops:
        for _ in range(cfg.U):
            h = dihgp_iter(0, h)
    else:
        h = jax.lax.fori_loop(0, cfg.U, dihgp_iter, h)

    # ---- outer hyper-gradient (Eq. 17b) and step ----
    def cross(xx):
        return tdot(jax.grad(g_fn, argnums=1)(xx, y, batch), h)
    cross_term = jax.grad(cross)(x)

    d_dir = taxpy(beta, cross_term, grad_x_f(x, y, batch))
    x_new = taxpy(-alpha, d_dir, ring_mix(x, axis, w, cd))  # Ẃx − α(...)

    metrics = {
        "outer_loss": f_fn(x, y, batch),
        "inner_loss": g_fn(x, y, batch),
        "hypergrad_norm": tnorm(d_dir),
        "consensus_x": tnorm(ring_laplacian(x, cfg.axis, w)),
    }  # consensus metric uses full-precision exchange (diagnostic)
    return x_new, y, metrics


def make_sharded_dagm(g_fn: Callable, f_fn: Callable,
                      cfg: ShardedDAGMConfig, mesh: Mesh,
                      x_spec=None, y_spec=None, batch_spec=None,
                      manual_axes=None, jit_step: bool = True):
    """Jitted global DAGM step over `mesh`.

    Global layout: x and y pytrees carry a leading agent axis of size
    n_agents = mesh size of cfg.axis (sharded 1-per-agent); batch leaves
    carry a leading agent axis likewise.

    `manual_axes` (default: {cfg.axis}) are the mesh axes shard_map
    handles manually; every other mesh axis (e.g. "model") is *auto* —
    GSPMD tensor-parallelizes the per-agent computation over it, so the
    paper's agent-parallel ring composes with model parallelism inside
    each agent (DESIGN.md §2: model-parallel sharding lives inside an
    agent).
    """
    ax = cfg.axis
    ax_names = ax if isinstance(ax, tuple) else (ax,)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in ax_names:
        n *= sizes[a]
    w = RingWeights.metropolis_ring(n)
    xs = x_spec if x_spec is not None else P(ax)
    ys = y_spec if y_spec is not None else P(ax)
    bs = batch_spec if batch_spec is not None else P(ax)
    manual = frozenset(manual_axes) if manual_axes is not None         else frozenset(ax_names)

    def local_step(x, y, batch):
        # strip the (size-1) leading agent axis inside the shard
        squeeze = lambda t: jax.tree.map(lambda a: a[0], t)
        expand = lambda t: jax.tree.map(lambda a: a[None], t)
        x1, y1, m = dagm_local_round(g_fn, f_fn, cfg, w,
                                     squeeze(x), squeeze(y), squeeze(batch))
        m = jax.tree.map(lambda s: jax.lax.pmean(s, ax), m)
        return expand(x1), expand(y1), m

    kw = {}
    if manual != frozenset(mesh.axis_names):
        kw["axis_names"] = manual
    step = shard_map(local_step, mesh=mesh, in_specs=(xs, ys, bs),
                     out_specs=(xs, ys, P()), check_vma=False, **kw)
    return (jax.jit(step) if jit_step else step), w
