"""Pod-scale DAGM: the paper's Algorithm 2 as a shard_map program.

Agents = slices of the mesh "data" axis (and "pod" × "data" multi-pod).
Each agent holds a *pytree* copy of the inner variable y (e.g. model
parameters) and the outer variable x (e.g. loss weights / regularizers),
plus its local data shard.  All cross-agent communication is
`lax.ppermute` neighbor exchange over a circulant graph (see
collectives.ring_mix) — vectors only, never matrices, exactly the
paper's communication pattern.

The inner Hessian-vector products use jvp-of-grad (matrix-free), and
DIHGP uses the scalar-preconditioned splitting of repro.core.dihgp
(D̃ = (β·c + 2(1−w_ii))I), so nothing larger than a parameter pytree is
ever materialized or communicated.

`dagm_sharded_step` is written against per-agent local views (it runs
*inside* shard_map); `make_sharded_dagm` wires it into a jitted global
step for a given mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import shard_map
from .collectives import (RingWeights, ring_laplacian, ring_laplacian_c,
                          ring_mix, ring_mix_c, taxpy, tdot, tnorm,
                          tscale, tsub, tadd)

Pytree = Any


class ShardedRoundCoeffs(NamedTuple):
    """One outer round's scalar coefficients, as jit operands.

    The sharded update algebra only ever *multiplies* by (combinations
    of) α, β and the scalar preconditioner D̃ — every reciprocal is
    taken on the host in float64, exactly as the legacy Python-float
    config did — so feeding these as traced f32 scalars reproduces the
    literal-constant program bit-for-bit while letting one compiled
    step serve any (αₖ, βₖ) schedule (`repro.solve` tier="sharded")."""
    neg_beta: Any       # −β   (inner DGD step)
    beta: Any           # β    (HVP + cross terms)
    d: Any              # D̃ = β·c + 2(1−w_ii)
    neg_inv_d: Any      # −1/D̃ (DIHGP init)
    inv_d: Any          # 1/D̃  (DIHGP rescale)
    neg_alpha: Any      # −α   (outer step)


def sharded_round_coeffs(alpha: float, beta: float, curvature: float,
                         w_self: float) -> ShardedRoundCoeffs:
    """Host-side (float64) coefficient math matching the legacy config
    path, rounded to f32 once at the use sites' precision."""
    d = beta * curvature + 2.0 * (1.0 - w_self)
    return ShardedRoundCoeffs(
        neg_beta=np.float32(-beta), beta=np.float32(beta),
        d=np.float32(d), neg_inv_d=np.float32(-1.0 / d),
        inv_d=np.float32(1.0 / d), neg_alpha=np.float32(-alpha))


@dataclasses.dataclass(frozen=True)
class ShardedDAGMConfig:
    """DEPRECATED — construct a `repro.solve.SolverSpec` with
    tier="sharded" (or the `repro.solve.sharded_spec(...)` kwargs
    mirror) instead.  Survives as a thin shim lowered by
    `repro.solve.spec.as_solver_spec`; every `repro.distributed` entry
    point accepts both.  Constructing one emits a DeprecationWarning
    once per process."""
    alpha: float = 1e-2
    beta: float = 1e-2
    M: int = 5                 # inner DGD steps per outer step
    U: int = 3                 # Neumann order
    curvature: float = 4.0     # c ≥ λmax(∇²_y g_i) bound (scalar precond)
    axis: str | tuple = "data"  # agent mesh axis; a tuple (e.g.
    #                             ("pod", "data")) rings the agents over
    #                             the flattened product of those axes —
    #                             the cross-pod ring of the multi-pod
    #                             DAGM dry-run
    comm_dtype: str = "f32"    # "bf16" = compressed gossip (§Perf
    #                            variant) — same "f32" | "bf16"
    #                            vocabulary as the reference tier's
    #                            DAGMConfig.mixing_dtype, resolved by the
    #                            shared topology.resolve_mixing_dtype
    comm: str = "identity"     # repro.comm gossip spec ("identity" |
    #                            "bf16" | "int8[+ef]" | "int4[+ef]" |
    #                            "top_k:<frac>[+ef]" | ...): the full
    #                            compressed-channel protocol around every
    #                            ppermute exchange.  Generalizes
    #                            comm_dtype — leaving comm="identity"
    #                            with comm_dtype="bf16" aliases to the
    #                            "bf16" policy (same wire), so existing
    #                            configs keep their behavior.  By default
    #                            error-feedback replicas are per-round
    #                            (they reset at each outer round boundary
    #                            so the step stays a pure (x, y, batch)
    #                            function); persist_ef threads them
    #                            across rounds instead.
    persist_ef: bool = False   # thread the EF `hat` replicas (and the
    #                            compressor key/send-counter state)
    #                            across outer rounds as an extra carry:
    #                            the step becomes (x, y, batch, channels)
    #                            -> (x, y, metrics, channels), matching
    #                            the reference tier where inner_y/outer_x
    #                            replicas warm-start every round (the
    #                            per-round dihgp_h variable still resets
    #                            its hat, like dagm_outer_step_c).  Open
    #                            the initial states with
    #                            `open_sharded_channels`.  Closes the
    #                            ROADMAP "EF state across outer rounds"
    #                            item; measured by bench_comm's
    #                            comm/sharded_ef rows.
    mix_every: int = 1         # j > 1: gossip only every j-th inner step
    #                            (local-updates variant, cf. FedNest [77];
    #                            §Perf — cuts inner comm by ~j)
    unroll_loops: bool = False  # Python-unroll the M/U loops so AOT
    #                             cost_analysis counts every iteration
    #                             (fori_loop bodies are counted once);
    #                             used by the dagm_dryrun accounting

    def __post_init__(self):
        from repro.solve._compat import warn_once
        warn_once(
            "ShardedDAGMConfig",
            "ShardedDAGMConfig is deprecated: use repro.solve."
            "SolverSpec with tier='sharded' (sharded_spec(...) mirrors "
            "these kwargs); make_sharded_dagm accepts it directly")

    @property
    def comm_jnp_dtype(self):
        from repro.topology import resolve_mixing_dtype
        return resolve_mixing_dtype(self.comm_dtype)

    @property
    def comm_policy(self):
        """Effective repro.comm policy: `comm` wins; the legacy
        comm_dtype="bf16" knob aliases to the "bf16" compressor."""
        from repro.comm import parse_comm_spec
        from repro.topology import resolve_mixing_dtype
        spec = self.comm
        if spec == "identity" and \
                resolve_mixing_dtype(self.comm_dtype) is not None:
            spec = self.comm_dtype
        return parse_comm_spec(spec)


def _as_sharded_cfg(cfg) -> ShardedDAGMConfig:
    """Normalize a SolverSpec (tier='sharded') or a legacy
    ShardedDAGMConfig to the internal per-round plan.  SolverSpec
    schedules contribute their round-0 constants (the raw step is one
    round per call; `repro.solve.solve` feeds per-round
    `ShardedRoundCoeffs` operands for real schedules)."""
    if isinstance(cfg, ShardedDAGMConfig):
        return cfg
    from repro.solve._compat import silently
    from repro.solve.spec import SolverSpec
    if not isinstance(cfg, SolverSpec):
        raise TypeError(
            f"expected SolverSpec or ShardedDAGMConfig, got "
            f"{type(cfg).__name__}")
    if cfg.curvature is None:
        raise ValueError(
            "the sharded tier's scalar-preconditioned DIHGP needs "
            "SolverSpec.curvature (a λmax bound on the local inner "
            "Hessians)")
    sched = cfg.schedule.materialize(max(cfg.K, 1))
    with silently():
        return ShardedDAGMConfig(
            alpha=float(sched.alpha[0]), beta=float(sched.beta[0]),
            M=cfg.M, U=cfg.U, curvature=cfg.curvature,
            axis=cfg.sharded.axis, comm_dtype=cfg.mixing.dtype,
            comm=cfg.comm.spec, persist_ef=cfg.comm.persist_ef,
            mix_every=cfg.sharded.mix_every,
            unroll_loops=cfg.sharded.unroll_loops)


def _agent_index(axis):
    """Flat agent index inside shard_map, for tuple axes too."""
    if isinstance(axis, tuple):
        idx = jnp.zeros((), jnp.int32)
        for a in axis:
            idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
        return idx
    return jax.lax.axis_index(axis)


def dagm_local_round(g_fn: Callable, f_fn: Callable,
                     cfg, w: RingWeights,
                     x: Pytree, y: Pytree, batch: Pytree,
                     key=None, channels: dict | None = None,
                     hp: ShardedRoundCoeffs | None = None,
                     flight_gamma=None):
    """One DAGM outer round from a single agent's perspective.

    g_fn(x, y, batch) -> scalar local inner loss  (strongly-convex-ish)
    f_fn(x, y, batch) -> scalar local outer loss
    Must be called inside shard_map over cfg.axis.
    Returns (x⁺, y⁺, metrics), plus the advanced channel dict when
    `channels` was given.

    Every ppermute exchange goes through the `cfg.comm_policy` channel
    (`collectives.ring_mix_c`): identity/bf16 policies reproduce the
    historical paths exactly; compressing policies open per-round
    error-feedback channels for y, h and x.  `key` feeds stochastic
    compressors (folded with the agent index so rows decorrelate); it
    is unused otherwise.

    `channels` (persist_ef mode): this agent's {"inner_y", "dihgp_h",
    "outer_x"} ChannelStates carried over from the previous round —
    EF replicas warm-start instead of reopening at zero (dihgp_h still
    resets its hat: the h vector itself re-initializes every round),
    keys advance inside the states, and the send counters accumulate
    across the whole run.  The caller threads the returned dict into
    the next round.

    `hp` (schedule mode): this round's `ShardedRoundCoeffs`, as traced
    scalars — `repro.solve`'s tier="sharded" driver feeds one per round
    so a single compiled step serves a whole (αₖ, βₖ) schedule.  None
    reproduces the config's constants (bit-identical: the coefficients
    are the very same host-float64 expressions either way).

    `flight_gamma` (flight-recorder mode): this round's penalty
    coefficient γₖ as a traced f32 scalar.  When set, two extra
    per-agent metrics are emitted for the flight row — `flight_gap_sq`
    (‖γ·(I−Ẃ)x + β·cross + ∇ₓf‖², this agent's share of the reference
    tier's Eq. 17b stationarity gap; the sharded update folds the
    γ·lap term into the Ẃx mixing, so it is reconstructed here) and
    `flight_consensus_sq` (‖x − x̄‖², whose agent-mean is exactly
    `consensus_error(x)`).  None — the default — leaves the metrics
    dict and the traced program untouched."""
    from repro.comm import channel_init
    cfg = _as_sharded_cfg(cfg)
    axis = cfg.axis
    if hp is None:
        hp = sharded_round_coeffs(cfg.alpha, cfg.beta, cfg.curvature,
                                  w.w_self)
    pol = cfg.comm_policy

    grad_y_g = jax.grad(g_fn, argnums=1)
    grad_x_f = jax.grad(f_fn, argnums=0)
    grad_y_f = jax.grad(f_fn, argnums=1)

    if channels is not None:
        st_y = channels["inner_y"]
        st_h = channels["dihgp_h"].reset_hat()
        st_x = channels["outer_x"]
    else:
        if pol.stochastic:
            if key is None:
                raise ValueError(
                    f"comm policy {pol.spec!r} draws stochastic "
                    f"compression noise: pass a fresh PRNG key per round "
                    f"(reusing one key would correlate the rounding "
                    f"across rounds and bias the gossip) — "
                    f"make_sharded_dagm's step takes it as its fourth "
                    f"argument")
            key = jax.random.fold_in(key, _agent_index(axis))
        elif key is None:
            key = jax.random.PRNGKey(0)     # threaded but never consumed
        ks = jax.random.split(key, 3)
        st_y = channel_init(pol, "inner_y", y, ks[0])
        st_h = channel_init(pol, "dihgp_h", y, ks[1])
        st_x = channel_init(pol, "outer_x", x, ks[2])

    # ---- inner loop: y ← W y − β ∇_y g  (Eq. 15/16), M rounds ----
    def inner(t, carry):
        yy, st = carry
        if cfg.unroll_loops:
            do_mix = (int(t) % cfg.mix_every) == cfg.mix_every - 1
            mixed, st = ring_mix_c(yy, axis, w, pol, st) if do_mix \
                else (yy, st)
        elif cfg.mix_every > 1:
            mixed, st = jax.lax.cond(
                t % cfg.mix_every == cfg.mix_every - 1,
                lambda z, s: ring_mix_c(z, axis, w, pol, s),
                lambda z, s: (z, s), yy, st)
        else:
            mixed, st = ring_mix_c(yy, axis, w, pol, st)
        return taxpy(hp.neg_beta, grad_y_g(x, yy, batch), mixed), st
    if cfg.unroll_loops:
        for t in range(cfg.M):
            y, st_y = inner(t, (y, st_y))
    else:
        y, st_y = jax.lax.fori_loop(0, cfg.M, inner, (y, st_y))

    # ---- DIHGP (Alg. 1, scalar-preconditioned, matrix-free) ----
    def hvp(v):
        return jax.jvp(lambda yy: grad_y_g(x, yy, batch), (y,), (v,))[1]

    def H_apply(hh, st):
        lap, st = ring_laplacian_c(hh, axis, w, pol, st)
        return taxpy(hp.beta, hvp(hh), lap), st

    p = grad_y_f(x, y, batch)
    h = tscale(hp.neg_inv_d, p)
    def dihgp_iter(_, carry):
        hh, st = carry
        bh_mix, st = H_apply(hh, st)
        bh = tsub(tscale(hp.d, hh), bh_mix)            # B̃ h
        return tscale(hp.inv_d, tsub(bh, p)), st
    if cfg.unroll_loops:
        for _ in range(cfg.U):
            h, st_h = dihgp_iter(0, (h, st_h))
    else:
        h, st_h = jax.lax.fori_loop(0, cfg.U, dihgp_iter, (h, st_h))

    # ---- outer hyper-gradient (Eq. 17b) and step ----
    def cross(xx):
        return tdot(jax.grad(g_fn, argnums=1)(xx, y, batch), h)
    cross_term = jax.grad(cross)(x)

    d_dir = taxpy(hp.beta, cross_term, grad_x_f(x, y, batch))
    mixed_x, st_x = ring_mix_c(x, axis, w, pol, st_x)
    x_new = taxpy(hp.neg_alpha, d_dir, mixed_x)        # Ẃx − α(...)

    metrics = {
        "outer_loss": f_fn(x, y, batch),
        "inner_loss": g_fn(x, y, batch),
        "hypergrad_norm": tnorm(d_dir),
        "consensus_x": tnorm(ring_laplacian(x, cfg.axis, w)),
        # gossip exchanges, from the traced channel counters (feeds
        # sharded_comm_ledger for the byte accounting): this round's
        # when channels reopen per round, cumulative under persist_ef
        "comm_sends": (st_y.sends + st_h.sends + st_x.sends)
        .astype(jnp.float32),
    }  # consensus metric uses full-precision exchange (diagnostic)
    if flight_gamma is not None:
        gamma = jnp.asarray(flight_gamma, jnp.float32)
        gap_t = tadd(tscale(gamma, ring_laplacian(x, cfg.axis, w)),
                     d_dir)
        xbar = jax.tree.map(lambda a: jax.lax.pmean(a, axis), x)
        metrics["flight_gap_sq"] = tdot(gap_t, gap_t).real
        diff = tsub(x, xbar)
        metrics["flight_consensus_sq"] = tdot(diff, diff).real
    if channels is not None:
        return x_new, y, metrics, \
            {"inner_y": st_y, "dihgp_h": st_h, "outer_x": st_x}
    return x_new, y, metrics


def make_sharded_dagm(g_fn: Callable, f_fn: Callable,
                      cfg, mesh: Mesh,
                      x_spec=None, y_spec=None, batch_spec=None,
                      manual_axes=None, jit_step: bool = True,
                      schedule_hp: bool = False, recorder=None):
    """Jitted global DAGM step over `mesh`.

    `cfg` is a `repro.solve.SolverSpec` (tier="sharded") or a legacy
    `ShardedDAGMConfig`.  With ``schedule_hp=True`` the returned step
    takes a trailing `ShardedRoundCoeffs` operand (replicated) so one
    compiled step serves a whole per-round schedule — the
    `repro.solve` tier="sharded" driver's mode.

    Global layout: x and y pytrees carry a leading agent axis of size
    n_agents = mesh size of cfg.axis (sharded 1-per-agent); batch leaves
    carry a leading agent axis likewise.

    `manual_axes` (default: {cfg.axis}) are the mesh axes shard_map
    handles manually; every other mesh axis (e.g. "model") is *auto* —
    GSPMD tensor-parallelizes the per-agent computation over it, so the
    paper's agent-parallel ring composes with model parallelism inside
    each agent (DESIGN.md §2: model-parallel sharding lives inside an
    agent).

    When `cfg.comm_policy` is stochastic (int8/int4/rand_k gossip) the
    returned step takes a fourth argument, a replicated PRNG key:
    ``step(x, y, batch, key)``; deterministic policies keep the
    historical 3-argument signature.

    With ``cfg.persist_ef`` the step instead carries the gossip channel
    states across rounds: ``step(x, y, batch, channels) -> (x, y,
    metrics, channels)`` with `channels` from `open_sharded_channels`
    (keys live inside the states, so stochastic policies need no
    per-round key argument in this mode).

    `recorder` (a `repro.obs.RecorderSpec`, needs ``schedule_hp=True``)
    threads a `FlightBuffer` through the step: the signature grows a
    trailing ``(gamma, rec)`` pair — this round's penalty coefficient
    γₖ (replicated f32 scalar) and the buffer — and the step returns
    the advanced buffer last, having appended one flight row per call
    (reference-tier field semantics: agent-summed Eq. 17b gap, γₖ ×
    consensus_error(x), *cumulative* exact wire bytes = round-count ×
    the one-round `sharded_comm_ledger` charge, alive fraction 1.0 —
    the sharded tier threads no fault masks).  The write is a pure
    `recorder_write` on the replicated metrics outside the shard_map
    body, so it adds no communication; with ``recorder=None`` the
    historical program is built untouched.
    """
    cfg = _as_sharded_cfg(cfg)
    ax = cfg.axis
    ax_names = ax if isinstance(ax, tuple) else (ax,)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in ax_names:
        n *= sizes[a]
    w = RingWeights.metropolis_ring(n)
    xs = x_spec if x_spec is not None else P(ax)
    ys = y_spec if y_spec is not None else P(ax)
    bs = batch_spec if batch_spec is not None else P(ax)
    manual = frozenset(manual_axes) if manual_axes is not None         else frozenset(ax_names)
    stochastic = cfg.comm_policy.stochastic

    squeeze = lambda t: jax.tree.map(lambda a: a[0], t)
    expand = lambda t: jax.tree.map(lambda a: a[None], t)

    def local_step(x, y, batch, key=None, hp=None):
        # strip the (size-1) leading agent axis inside the shard
        x1, y1, m = dagm_local_round(g_fn, f_fn, cfg, w,
                                     squeeze(x), squeeze(y),
                                     squeeze(batch), key=key, hp=hp)
        m = jax.tree.map(lambda s: jax.lax.pmean(s, ax), m)
        return expand(x1), expand(y1), m

    def local_step_persist(x, y, batch, cs, hp=None):
        x1, y1, m, cs1 = dagm_local_round(g_fn, f_fn, cfg, w,
                                          squeeze(x), squeeze(y),
                                          squeeze(batch),
                                          channels=squeeze(cs), hp=hp)
        m = jax.tree.map(lambda s: jax.lax.pmean(s, ax), m)
        return expand(x1), expand(y1), m, expand(cs1)

    kw = {}
    if manual != frozenset(mesh.axis_names):
        kw["axis_names"] = manual
    if recorder is not None:
        if not schedule_hp:
            raise ValueError(
                "the sharded flight recorder needs schedule_hp=True: "
                "each row carries that round's penalty coefficient γₖ, "
                "which only exists as a traced operand in schedule "
                "mode (repro.solve's tier='sharded' driver)")
        return _make_recorded_step(g_fn, f_fn, cfg, mesh, w, n,
                                   xs, ys, bs, kw, stochastic,
                                   squeeze, expand, jit_step), w
    if cfg.persist_ef:
        if schedule_hp:
            step = shard_map(local_step_persist, mesh=mesh,
                             in_specs=(xs, ys, bs, P(ax), P()),
                             out_specs=(xs, ys, P(), P(ax)),
                             check_vma=False, **kw)
        else:
            step = shard_map(lambda x, y, b, cs:
                             local_step_persist(x, y, b, cs),
                             mesh=mesh, in_specs=(xs, ys, bs, P(ax)),
                             out_specs=(xs, ys, P(), P(ax)),
                             check_vma=False, **kw)
    elif stochastic:
        if schedule_hp:
            step = shard_map(local_step, mesh=mesh,
                             in_specs=(xs, ys, bs, P(), P()),
                             out_specs=(xs, ys, P()), check_vma=False,
                             **kw)
        else:
            step = shard_map(lambda x, y, b, k: local_step(x, y, b, k),
                             mesh=mesh, in_specs=(xs, ys, bs, P()),
                             out_specs=(xs, ys, P()), check_vma=False,
                             **kw)
    elif schedule_hp:
        step = shard_map(lambda x, y, b, hp:
                         local_step(x, y, b, hp=hp),
                         mesh=mesh, in_specs=(xs, ys, bs, P()),
                         out_specs=(xs, ys, P()), check_vma=False, **kw)
    else:
        step = shard_map(lambda x, y, b: local_step(x, y, b),
                        mesh=mesh, in_specs=(xs, ys, bs),
                        out_specs=(xs, ys, P()), check_vma=False, **kw)
    if not jit_step:
        return step, w
    # jit through the shared obs trace counter: the sharded tier's
    # host-driven round loop calls this step K times, so a retrace
    # (anything but jit_traces_total{name="sharded_dagm_step"} == 1
    # per program) would multiply compile cost K-fold — the same
    # zero-retrace telemetry the serve engine and benches publish
    from repro.obs import TraceCounter
    return TraceCounter("sharded_dagm_step").wrap(step), w


def _make_recorded_step(g_fn, f_fn, cfg, mesh, w, n, xs, ys, bs, kw,
                        stochastic, squeeze, expand, jit_step):
    """The flight-recorder twin of `make_sharded_dagm`'s step builder
    (kept separate so the recorder-off construction stays literally the
    historical code).  See `make_sharded_dagm` for the signature the
    returned step exposes."""
    from repro.obs import TraceCounter
    from repro.obs.recorder import recorder_write
    ax = cfg.axis

    def local_flight(x, y, batch, key=None, hp=None, gamma=None):
        x1, y1, m = dagm_local_round(
            g_fn, f_fn, cfg, w, squeeze(x), squeeze(y), squeeze(batch),
            key=key, hp=hp, flight_gamma=gamma)
        m = jax.tree.map(lambda s: jax.lax.pmean(s, ax), m)
        return expand(x1), expand(y1), m

    def local_flight_persist(x, y, batch, cs, hp=None, gamma=None):
        x1, y1, m, cs1 = dagm_local_round(
            g_fn, f_fn, cfg, w, squeeze(x), squeeze(y), squeeze(batch),
            channels=squeeze(cs), hp=hp, flight_gamma=gamma)
        m = jax.tree.map(lambda s: jax.lax.pmean(s, ax), m)
        return expand(x1), expand(y1), m, expand(cs1)

    def _round_bytes(x, y) -> float:
        # host constant captured at trace time: one round's exact
        # ledger charge, from per-agent leaf *shapes* only
        local = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype),
            (x, y))
        return float(sharded_comm_ledger(
            cfg, local[0], local[1], rounds=1).total_bytes)

    def _write_row(m, gamma, rec, x, y):
        m = dict(m)
        # pmean gave agent means; the reference gap is the agent *sum*,
        # while consensus_error already divides by n — see FIELDS docs
        gap = m.pop("flight_gap_sq") * np.float32(n)
        cons = m.pop("flight_consensus_sq")
        wire = (rec.count + 1).astype(jnp.float32) \
            * jnp.float32(_round_bytes(x, y))
        rec = recorder_write(rec, {
            "outer_gap_sq": gap,
            "penalty": jnp.asarray(gamma, jnp.float32) * cons,
            "wire_bytes": wire,
            "alive_fraction": jnp.ones((), jnp.float32)})
        return m, rec

    if cfg.persist_ef:
        core = shard_map(local_flight_persist, mesh=mesh,
                         in_specs=(xs, ys, bs, P(ax), P(), P()),
                         out_specs=(xs, ys, P(), P(ax)),
                         check_vma=False, **kw)

        def step(x, y, batch, cs, hp, gamma, rec):
            x1, y1, m, cs1 = core(x, y, batch, cs, hp, gamma)
            m, rec = _write_row(m, gamma, rec, x, y)
            return x1, y1, m, cs1, rec
    elif stochastic:
        core = shard_map(local_flight, mesh=mesh,
                         in_specs=(xs, ys, bs, P(), P(), P()),
                         out_specs=(xs, ys, P()), check_vma=False,
                         **kw)

        def step(x, y, batch, key, hp, gamma, rec):
            x1, y1, m = core(x, y, batch, key, hp, gamma)
            m, rec = _write_row(m, gamma, rec, x, y)
            return x1, y1, m, rec
    else:
        core = shard_map(lambda x, y, b, hp, gamma:
                         local_flight(x, y, b, hp=hp, gamma=gamma),
                         mesh=mesh, in_specs=(xs, ys, bs, P(), P()),
                         out_specs=(xs, ys, P()), check_vma=False,
                         **kw)

        def step(x, y, batch, hp, gamma, rec):
            x1, y1, m = core(x, y, batch, hp, gamma)
            m, rec = _write_row(m, gamma, rec, x, y)
            return x1, y1, m, rec

    if not jit_step:
        return step
    return TraceCounter("sharded_dagm_step").wrap(step)


def open_sharded_channels(cfg, x: Pytree, y: Pytree,
                          seed: int = 0) -> dict:
    """Globally-stacked gossip ChannelStates for the persist_ef step.

    `x` / `y` are the *global* pytrees with a leading agent axis n
    (sharded 1-per-agent, the same layout `make_sharded_dagm` expects):
    each agent's slice holds its EF replica (zeros at open), its
    compressor PRNG key (decorrelated by agent index, the same fold-in
    protocol `dagm_local_round` uses when reopening per round) and its
    traced send counter.  Shard with `P(cfg.axis)` — the step's
    in/out_specs already do."""
    from repro.comm import ChannelState
    pol = _as_sharded_cfg(cfg).comm_policy
    n = jax.tree.leaves(y)[0].shape[0]
    keys = jax.vmap(lambda i: jax.random.split(
        jax.random.fold_in(jax.random.PRNGKey(seed), i), 3))(
            jnp.arange(n))                                    # (n, 3, 2)

    def mk(name, tpl, k):
        if pol.ef:
            hat = jax.tree.map(jnp.zeros_like, tpl)
        else:
            hat = jnp.zeros((n,), jnp.float32)
        return ChannelState(hat=hat, key=k,
                            sends=jnp.zeros((n,), jnp.int32), name=name)

    return {"inner_y": mk("inner_y", y, keys[:, 0]),
            "dihgp_h": mk("dihgp_h", y, keys[:, 1]),
            "outer_x": mk("outer_x", x, keys[:, 2])}


def sharded_comm_ledger(cfg, x: Pytree, y: Pytree,
                        rounds: int = 1):
    """Byte-accurate CommLedger for the sharded DAGM round.

    `x` / `y` are one agent's *local* pytrees (or the stacked globals —
    only leaf shapes after the agent axis matter is the caller's
    responsibility; pass local views).  Per-leaf wire cost uses the
    configured `comm_policy` compressor, one row per leaf — exactly
    what `ring_mix_c` transmits.  Sends per round mirror the local
    round's loop structure (inner M//mix_every, DIHGP U, outer 1); the
    `comm_sends` metric emitted by `dagm_local_round` cross-checks the
    total at runtime.  The diagnostic full-precision consensus exchange
    is excluded (it is not part of the algorithm's traffic)."""
    from repro.comm import CommLedger
    cfg = _as_sharded_cfg(cfg)
    comp = cfg.comm_policy.compressor
    spec = cfg.comm_policy.spec

    def tree_cost(tree):
        leaves = jax.tree.leaves(tree)
        return (sum(comp.payload_bytes(l.shape) for l in leaves),
                sum(comp.payload_floats(l.shape) for l in leaves))

    inner_sends = sum(1 for t in range(cfg.M)
                      if t % cfg.mix_every == cfg.mix_every - 1)
    led = CommLedger("dagm_sharded")
    for name, tree, per_round in (("inner_y", y, inner_sends),
                                  ("dihgp_h", y, cfg.U),
                                  ("outer_x", x, 1)):
        bytes_per, floats_per = tree_cost(tree)
        led.add_channel(name, (floats_per,), spec=spec,
                        sends=rounds * per_round,
                        floats_per_send=floats_per,
                        bytes_per_send=bytes_per)
    return led
