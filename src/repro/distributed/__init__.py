from .sharding import (ShardingRules, make_rules, use_rules, shard,
                       current_rules, tree_param_sharding)
