"""Distributed tier: sharding rules, ring collectives, sharded DAGM.

Also home of the version-compatible `shard_map` shim: newer jax exposes
`jax.shard_map(..., axis_names=..., check_vma=...)`, while 0.4.x only
has `jax.experimental.shard_map.shard_map(..., auto=..., check_rep=...)`.
All shard_map users in this repo (dagm_sharded, models.moe, tests,
examples) import it from here so the version split lives in one place.
"""
from __future__ import annotations

from .sharding import (ShardingRules, make_rules, use_rules, shard,
                       current_rules, tree_param_sharding)

import jax as _jax

#: True when jax ships the stable `jax.shard_map` API.  Callers that
#: need *partially-auto* shard_map (manual over some mesh axes, GSPMD
#: auto over the rest) must check this: on jax 0.4.x the experimental
#: `auto=` escape hatch check-fails in the SPMD partitioner for programs
#: with sharding constraints inside the manual region.
HAS_NATIVE_SHARD_MAP = hasattr(_jax, "shard_map")

if HAS_NATIVE_SHARD_MAP:
    shard_map = _jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
                  axis_names=None, **kw):
        """jax<0.5 fallback: check_vma → check_rep; `axis_names` (the
        *manual* axes) → `auto` (its complement over the mesh)."""
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        else:
            auto = frozenset()
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma,
                              auto=auto, **kw)
