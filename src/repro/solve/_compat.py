"""Deprecation machinery for the legacy solver surfaces.

The `repro.solve` redesign keeps `DAGMConfig`, `ShardedDAGMConfig` and
the baseline ``alpha=/beta=`` kwargs alive as thin shims that lower
onto `SolverSpec`.  Each shim announces itself with a
`DeprecationWarning` **exactly once per process** (a module-level
registry, not the `warnings` module's per-location dedup, so the
guarantee is deterministic under pytest's filter resets), and internal
code constructs the legacy dataclasses through `silently()` so no
library call site ever triggers a warning — regression-tested under
``-W error::DeprecationWarning``.
"""
from __future__ import annotations

import contextlib
import warnings

_warned: set[str] = set()
_silent_depth = 0


def warn_once(key: str, message: str, stacklevel: int = 3) -> None:
    """Emit `message` as a DeprecationWarning the first time `key` is
    seen in this process; later calls are no-ops.  Suppressed entirely
    inside a `silently()` block (internal lowering)."""
    if _silent_depth or key in _warned:
        return
    _warned.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)


@contextlib.contextmanager
def silently():
    """Internal-use scope: legacy constructors inside do not warn (the
    shims lower through the very classes they deprecate)."""
    global _silent_depth
    _silent_depth += 1
    try:
        yield
    finally:
        _silent_depth -= 1


def reset_deprecation_state() -> None:
    """Forget which warnings fired (tests asserting the exactly-once
    contract call this to get a clean slate)."""
    _warned.clear()
