"""`solve(problem, network, spec)` — the single solver front-end.

One call signature dispatches every method × tier combination:

    from repro.solve import SolverSpec, ScheduleSpec, solve
    res = solve(prob, net, SolverSpec(
        method="dagm", tier="reference", K=200, M=10, U=3,
        schedule=ScheduleSpec(alpha=inverse_sqrt_schedule(0.05),
                              beta=0.1)))

* ``tier="reference"`` — one jitted K-round scan (methods "dagm",
  "dgbo", "dgtbo", "ma_dbo", "fednest").  Hyper-parameter schedules
  enter the compiled program as traced (K,) operands, so the program
  itself is schedule-agnostic; callers that hold a compiled runner
  (the serve engine's chunk cache, or your own jit around
  `dagm_run_chunk`) sweep α/β/γ with zero retraces.  A bare `solve()`
  call builds a fresh closure per invocation and does not cache
  compiles across calls — route sweeps through ``tier="serve"`` (one
  engine, one compile per bucket program).
* ``tier="serve"``   — the run rides the `repro.serve` engine as a
  one-job bucket (same chunk machinery, width-padded).  Because solo
  and serve now share the traced-operand program, the trajectories are
  bit-exact across tiers.
* ``tier="sharded"`` — the `distributed` shard_map program over a
  caller-supplied mesh; per-round coefficient operands feed the same
  schedules into one compiled step.

Every tier returns a `SolveResult` (final iterates, per-round metric
trajectory, byte-accurate CommLedger, final gossip channel states).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp

from .spec import (SolverSpec, as_solver_spec, mixing_kwargs,
                   validate_spec)

Array = jnp.ndarray


@dataclasses.dataclass
class SolveResult:
    """Unified outcome of a `solve` call, across methods and tiers."""
    x: Array                     # final stacked outer iterates (n, d1)
    y: Array                     # final stacked inner iterates (n, d2)
    metrics: dict[str, Array]    # per-outer-round traces
    ledger: Any = None           # repro.comm.CommLedger (measured)
    channels: Any = None         # final gossip ChannelStates (or None)
    method: str = "dagm"
    tier: str = "reference"
    extras: dict = dataclasses.field(default_factory=dict)
    #   method/tier specifics: baselines put the Appendix-S1
    #   "comm_floats_per_round" closed form + display "name" here; the
    #   serve tier puts rounds/converged/final_gap/wire bytes.


def solve(problem, network, spec, *, x0=None, y0=None, seed: int = 0,
          metrics_fn: Callable | None = None, mesh=None,
          g_fn: Callable | None = None, f_fn: Callable | None = None,
          batch=None, serve_engine=None, recorder=None) -> SolveResult:
    """Run `spec` on (problem, network) and return a `SolveResult`.

    problem:  a `core.problems.BilevelProblem` (stacked per-agent
              objectives).  The sharded tier can instead take raw
              `g_fn`/`f_fn` pytree objectives (+ explicit x0/y0/batch).
    network:  a `repro.topology.Network`; ignored by tier="sharded"
              (the mesh's ring is the topology) and "fednest" (star).
    spec:     `SolverSpec` (legacy DAGMConfig/ShardedDAGMConfig configs
              are lowered transparently).
    x0/y0:    optional initial stacked iterates (reference/sharded).
    seed:     y0 draw + gossip channel keys.
    metrics_fn: per-round metrics callback (method="dagm" only).
    mesh:     jax Mesh, required by tier="sharded".
    serve_engine: optional pre-built `repro.serve.ServeEngine` to run
              tier="serve" solves through (shares its compile cache).
              A `repro.serve.admission.AdmissionLoop` works too: the
              solve is submitted into the live service and joins a
              bucket at the next chunk boundary, sharing slots with
              whatever jobs the loop is already running.
    recorder: optional `repro.obs.RecorderSpec` — threads the in-jit
              flight recorder through the run (the chunk carry on the
              reference/serve tiers, the shard_map step carry on the
              sharded tier) and returns the per-round rows in
              `extras["flight"]` (method="dagm", all three tiers).
              None (the default) leaves every program byte-for-byte
              as before.
    """
    spec = as_solver_spec(spec)
    validate_spec(spec)
    if metrics_fn is not None and spec.method != "dagm":
        raise ValueError(
            f"metrics_fn is only supported for method='dagm' (the "
            f"baselines record the fixed default_metrics trace); got "
            f"method={spec.method!r}")
    if recorder is not None and spec.method != "dagm":
        raise ValueError(
            "the flight recorder rides the dagm round carry: "
            "recorder= needs method='dagm' (the baselines record no "
            "flight rows) — got method=" + repr(spec.method))
    if spec.tier == "reference":
        if spec.method == "dagm":
            return _solve_dagm_reference(problem, network, spec, x0=x0,
                                         y0=y0, seed=seed,
                                         metrics_fn=metrics_fn,
                                         recorder=recorder)
        return _solve_baseline(problem, network, spec, x0=x0, y0=y0,
                               seed=seed)
    if spec.tier == "serve":
        return _solve_serve(problem, network, spec, x0=x0, y0=y0,
                            seed=seed, metrics_fn=metrics_fn,
                            engine=serve_engine, recorder=recorder)
    return _solve_sharded(problem, network, spec, x0=x0, y0=y0,
                          seed=seed, metrics_fn=metrics_fn, mesh=mesh,
                          g_fn=g_fn, f_fn=f_fn, batch=batch,
                          recorder=recorder)


# ---------------------------------------------------------------------------
# reference tier
# ---------------------------------------------------------------------------

def _schedule_hp(spec: SolverSpec):
    from repro.core.dagm import RoundHP
    sched = spec.schedule.materialize(spec.K)
    return RoundHP(alpha=sched.alpha, beta=sched.beta,
                   gamma=sched.gamma)


def _dagm_phases(spec: SolverSpec):
    """(label, gossip-weight) pairs for the synthesized per-round phase
    spans: M inner DGD exchanges, U DIHGP Neumann exchanges (0 when the
    dense-solve backend never gossips h), 1 outer (I−Ẃ)x exchange."""
    u = 0 if spec.dihgp == "exact" else spec.U
    return [("inner_dgd", spec.M), ("dihgp_neumann", u),
            ("outer_step", 1)]


def _solve_dagm_reference(prob, net, spec: SolverSpec, *, x0, y0, seed,
                          metrics_fn, recorder=None) -> SolveResult:
    from repro.core.dagm import (RoundHP, dagm_init_carry,
                                 dagm_run_chunk)
    from repro.core.mixing import make_mixing_op
    from repro import obs
    tr = obs.tracer()
    with tr.span("solve", cat="solver", track="solver", method="dagm",
                 tier="reference", K=spec.K, seed=seed):
        W = make_mixing_op(net, **mixing_kwargs(spec))
        with tr.span("init_carry", cat="solver", track="solver"):
            carry0 = dagm_init_carry(prob, W, spec, x0, y0, seed,
                                     recorder=recorder)
        hp = _schedule_hp(spec)

        # faults lower once (host-side) to a per-round mask operand;
        # like hp, the masks enter the program as traced arrays, so
        # resolving a different FaultSpec against a held compiled
        # runner costs zero retraces (the bare solve() closure is
        # still per-call).
        trace = None
        masks = None
        if spec.faults is not None:
            from repro.faults import lower_faults
            with tr.span("lower_faults", cat="solver", track="solver"):
                trace = lower_faults(spec.faults, net, spec.K)
                masks = jnp.asarray(trace.table_masks(W.sparse),
                                    jnp.float32)

        # hp enters as a jit *argument*: the program is
        # schedule-agnostic, and — because the serve tier scans the
        # very same traced operands — batched traced-hp runs are
        # bit-exact with this solo program.  (The closure itself is
        # per-call: solo solve() does not cache compiles across
        # invocations; sweeps belong on tier="serve".)
        @jax.jit
        def run(carry, hp, masks):
            return dagm_run_chunk(prob, W, spec, carry, spec.K,
                                  metrics_fn, hp=hp, masks=masks,
                                  recorder=recorder)

        t0 = tr.now_us()
        out = run(
            carry0, RoundHP(*(jnp.asarray(a, jnp.float32) for a in hp)),
            masks)
        t_disp = tr.now_us()
        if tr.enabled:
            # the call above returned once tracing+compile+dispatch
            # finished; waiting here makes the chunk span cover the
            # device execution (a sync the result read below would
            # force anyway — values are unchanged)
            jax.block_until_ready(out)
        t1 = tr.now_us()

        flight = None
        if recorder is not None:
            ((x, y), cs, rec), metrics = out
            flight = obs.recorder_rows(rec)
        else:
            ((x, y), cs), metrics = out
        W.ledger.charge_states(cs.values())

        if tr.enabled:
            tr.add_span("trace_compile", t0, t_disp - t0,
                        cat="solver.compile", track="solver",
                        rounds=spec.K)
            tr.add_span("chunk", t_disp, t1 - t_disp,
                        cat="solver.chunk", track="solver",
                        rounds=spec.K)
            obs.synthesize_round_spans(
                tr, t0_us=t_disp, dur_us=t1 - t_disp, rounds=spec.K,
                phases=_dagm_phases(spec), track="solver",
                round_args=(obs.rows_to_dicts(flight)
                            if flight is not None else None))

        extras = {}
        if trace is not None:
            # ledger sends stay nominal (channel counters tick whether
            # or not a given link carried the payload); the honest
            # wire scale for the faulted run is the trace's
            # realized-link fraction
            extras = {"fault_trace": trace,
                      "fault_alive_fraction": trace.alive_fraction()}
        if flight is not None:
            extras["flight"] = flight
        return SolveResult(x=x, y=y, metrics=metrics, ledger=W.ledger,
                           channels=cs, method="dagm",
                           tier="reference", extras=extras)


def _solve_baseline(prob, net, spec: SolverSpec, *, x0, y0, seed
                    ) -> SolveResult:
    from repro.core.baselines import BASELINE_SOLVERS
    hp = _schedule_hp(spec)
    x, y, metrics, cs, ledger, floats, name = \
        BASELINE_SOLVERS[spec.method](prob, net, spec, hp, x0=x0, y0=y0,
                                      seed=seed)
    return SolveResult(x=x, y=y, metrics=metrics, ledger=ledger,
                       channels=cs, method=spec.method, tier="reference",
                       extras={"comm_floats_per_round": floats,
                               "name": name})


# ---------------------------------------------------------------------------
# serve tier
# ---------------------------------------------------------------------------

#: problem-object → inline family callable.  The family object is part
#: of the serve compile signature, so re-solving the same problem must
#: hand the engine the SAME callable or a shared engine's compile cache
#: could never hit.  id-keyed (BilevelProblem holds arrays and is not
#: hashable) with an identity check against stale-id reuse; bounded
#: because each family closure keeps its problem alive.
_INLINE_FAMILIES: dict = {}
_INLINE_FAMILIES_CAP = 256


def _inline_family(prob):
    ent = _INLINE_FAMILIES.get(id(prob))
    if ent is not None and ent[0] is prob:
        return ent[1]
    fam = lambda: prob
    while len(_INLINE_FAMILIES) >= _INLINE_FAMILIES_CAP:
        _INLINE_FAMILIES.pop(next(iter(_INLINE_FAMILIES)))
    _INLINE_FAMILIES[id(prob)] = (prob, fam)
    return fam


def _default_serve_metrics(prob, W, x, y):
    """Module-level (stable identity: it is part of the engine's chunk
    compile key) default — the reference tier's default_metrics, so a
    serve-tier SolveResult carries the same trajectory."""
    from repro.core.dagm import default_metrics
    return default_metrics(prob, x, y)


def _solve_serve(prob, net, spec: SolverSpec, *, x0, y0, seed,
                 metrics_fn, engine, recorder=None) -> SolveResult:
    from repro.serve import JobSpec, ServeEngine
    if x0 is not None or y0 is not None:
        raise ValueError(
            "tier='serve' jobs initialize from their seed (the engine's "
            "slot-admission protocol); custom x0/y0 are a "
            "reference-tier feature — use tier='reference' or bake the "
            "init into the problem")
    if engine is None:
        engine = ServeEngine(record_metrics=True,
                             flight_recorder=recorder)
    elif not engine.record_metrics:
        raise ValueError(
            "the ServeEngine passed to solve(tier='serve') must be "
            "built with record_metrics=True so the SolveResult can "
            "carry the per-round metric trajectory")
    elif recorder is not None \
            and engine.flight_recorder != recorder:
        raise ValueError(
            "solve(recorder=...) on a pre-built engine needs the "
            "engine constructed with the same flight_recorder= spec "
            "(the recorder buffer is part of every bucket's carry)")
    mf = _default_serve_metrics if metrics_fn is None else metrics_fn
    job = JobSpec(family=_inline_family(prob), problem={},
                  config=dataclasses.replace(spec, tier="reference"),
                  graph=net, seed=seed)
    prev_mf = engine.metrics_fn
    engine.metrics_fn = mf
    try:
        engine.submit(job)
        (res,) = engine.run()
    finally:
        engine.metrics_fn = prev_mf
    extras = {"rounds": res.rounds, "converged": res.converged,
              "final_gap": res.final_gap,
              "wire_bytes": res.wire_bytes,
              "wire_floats": res.wire_floats, "sends": res.sends}
    if recorder is not None:
        extras["flight"] = res.flight
    return SolveResult(
        x=jnp.asarray(res.x), y=jnp.asarray(res.y), metrics=res.metrics,
        ledger=engine.ledgers[res.signature], channels=None,
        method="dagm", tier="serve", extras=extras)


# ---------------------------------------------------------------------------
# sharded tier
# ---------------------------------------------------------------------------

def _solve_sharded(prob, net, spec: SolverSpec, *, x0, y0, seed,
                   metrics_fn, mesh, g_fn, f_fn, batch,
                   recorder=None) -> SolveResult:
    from repro.distributed.dagm_sharded import (ShardedRoundCoeffs,
                                                make_sharded_dagm,
                                                open_sharded_channels,
                                                sharded_comm_ledger,
                                                sharded_round_coeffs)
    if mesh is None:
        raise ValueError(
            "tier='sharded' runs a shard_map program: pass the jax "
            "Mesh via solve(..., mesh=...) (its "
            f"{spec.sharded.axis!r} axis sizes the agent ring); build "
            "one with jax.sharding.Mesh or repro.launch.mesh")
    if metrics_fn is not None:
        raise ValueError(
            "tier='sharded' records the fixed in-shard metrics "
            "(outer/inner loss, hypergrad norm, consensus, comm "
            "sends); a custom metrics_fn is a reference-tier feature")
    if g_fn is None or f_fn is None:
        if prob is None:
            raise ValueError(
                "tier='sharded' needs objectives: pass a BilevelProblem "
                "as `problem`, or explicit g_fn/f_fn pytree objectives "
                "(with x0/y0/batch)")
        g_fn = g_fn or prob.g
        f_fn = f_fn or prob.f
    if batch is None:
        if prob is None:
            raise ValueError(
                "tier='sharded' with raw g_fn/f_fn needs the stacked "
                "per-agent `batch` pytree (leading agent axis)")
        batch = prob.data

    step, w = make_sharded_dagm(g_fn, f_fn, spec, mesh,
                                schedule_hp=True, recorder=recorder)
    ax = spec.sharded.axis
    ax_names = ax if isinstance(ax, tuple) else (ax,)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in ax_names:
        n *= sizes[a]
    if x0 is None:
        if prob is None:
            raise ValueError(
                "tier='sharded' with raw g_fn/f_fn needs explicit "
                "x0/y0 stacked iterates (the shapes are not inferable)")
        x0 = jnp.zeros((n, prob.d1), jnp.float32)
    if y0 is None:
        y0 = 0.01 * jax.random.normal(jax.random.PRNGKey(seed),
                                      (n, prob.d2), jnp.float32)

    sched = spec.schedule.materialize(spec.K)
    pol = _sharded_policy(spec)
    channels = open_sharded_channels(spec, x0, y0, seed) \
        if spec.comm.persist_ef else None
    x, y = x0, y0
    rows = []
    from repro import obs
    rec = obs.recorder_init(recorder) if recorder is not None else None
    tr = obs.tracer()
    # the sharded tier's round loop is host-driven, so — unlike the
    # reference/serve scans — these per-round spans are real wall-clock
    # measurements (each round's metric read below syncs the device)
    with tr.span("solve", cat="solver", track="solver", method="dagm",
                 tier="sharded", K=spec.K, seed=seed):
        for k in range(spec.K):
            hp = ShardedRoundCoeffs(*(jnp.float32(c) for c in
                                      sharded_round_coeffs(
                                          float(sched.alpha[k]),
                                          float(sched.beta[k]),
                                          spec.curvature, w.w_self)))
            with tr.span("outer_round", cat="solver.round",
                         track="solver", round=k):
                if rec is not None:
                    gamma = jnp.float32(sched.gamma[k])
                    if channels is not None:
                        x, y, m, channels, rec = step(
                            x, y, batch, channels, hp, gamma, rec)
                    elif pol.stochastic:
                        key = jax.random.fold_in(
                            jax.random.PRNGKey(seed ^ 0x5eed), k)
                        x, y, m, rec = step(x, y, batch, key, hp,
                                            gamma, rec)
                    else:
                        x, y, m, rec = step(x, y, batch, hp, gamma,
                                            rec)
                elif channels is not None:
                    x, y, m, channels = step(x, y, batch, channels, hp)
                elif pol.stochastic:
                    key = jax.random.fold_in(
                        jax.random.PRNGKey(seed ^ 0x5eed), k)
                    x, y, m = step(x, y, batch, key, hp)
                else:
                    x, y, m = step(x, y, batch, hp)
                rows.append(jax.tree.map(np.asarray, m))
    metrics = {key: np.stack([r[key] for r in rows]) for key in rows[0]}
    local = jax.tree.map(lambda a: a[0], (x0, y0))
    ledger = sharded_comm_ledger(spec, local[0], local[1],
                                 rounds=spec.K)
    extras = {"ring": w}
    if rec is not None:
        extras["flight"] = obs.recorder_rows(rec)
    return SolveResult(x=x, y=y, metrics=metrics, ledger=ledger,
                       channels=channels, method="dagm", tier="sharded",
                       extras=extras)


def _sharded_policy(spec: SolverSpec):
    from repro.comm import parse_comm_spec
    return parse_comm_spec(spec.comm.spec)
