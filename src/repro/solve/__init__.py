"""repro.solve — one solver front-end across every method and tier.

The paper's penalty-based DAGM, the comparison baselines, the sharded
shard_map program and the batched serve engine all run through

    solve(problem, network, SolverSpec(method=..., tier=..., ...))

with layered frozen specs: `ScheduleSpec` (runtime αₖ/βₖ/γₖ
sequences — decaying step sizes, growing penalties), `MixingSpec`
(topology execution backend), `CommSpec` (compressed-gossip wire) and
`ShardedSpec` (mesh wiring).  Hyper-parameters are traced per-round
operands everywhere, so a compiled chunk/bucket program serves any
sweep (the serve engine's cache retraces nothing across waves) and
the serve tier's batched runs are bit-exact with solo runs.

Legacy surfaces (`DAGMConfig`/`dagm_run`, `ShardedDAGMConfig`, the
baselines' ``alpha=/beta=`` kwargs) survive as deprecation shims that
lower onto `SolverSpec`; constant schedules reproduce their historical
trajectories bit-for-bit.
"""
from ._compat import reset_deprecation_state, silently, warn_once
from .api import SolveResult, solve
from .spec import (METHODS, TIERS, CommSpec, MixingSpec, RoundSchedules,
                   ScheduleSpec, ShardedSpec, SolverSpec, as_solver_spec,
                   dagm_spec, mixing_kwargs, sharded_spec, validate_spec)

__all__ = [
    "CommSpec", "METHODS", "MixingSpec", "RoundSchedules",
    "ScheduleSpec", "ShardedSpec", "SolveResult", "SolverSpec", "TIERS",
    "as_solver_spec", "dagm_spec", "mixing_kwargs",
    "reset_deprecation_state", "sharded_spec", "silently", "solve",
    "validate_spec", "warn_once",
]
