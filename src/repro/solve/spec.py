"""Layered solver specification for the `repro.solve` front-end.

One frozen pytree-of-specs describes a complete decentralized bilevel
run across every tier:

    SolverSpec(method="dagm", tier="reference", K=..., M=..., U=...,
               schedule=ScheduleSpec(alpha=..., beta=..., gamma=...),
               mixing=MixingSpec(...), comm=CommSpec(...),
               sharded=ShardedSpec(...))

* `ScheduleSpec` — the run's hyper-parameter *sequences*.  Each of
  α/β/γ is a constant, a `repro.optim` schedule callable, or an
  explicit length-K tuple; `materialize()` lowers all three to (K,)
  float32 arrays that enter the compiled programs as **traced
  per-round operands**.  One compile therefore serves any sweep, and
  the paper's decaying-αₖ/βₖ, growing-γₖ corollaries become runnable.
* `MixingSpec` — the (I−W)·Y execution backend (repro.topology).
* `CommSpec`   — the gossip wire policy (repro.comm) + EF persistence.
* `ShardedSpec`— mesh wiring knobs of the `distributed` tier.

Bit-exactness contract: with constant schedules the traced-operand
programs reproduce the legacy literal-hyper-parameter trajectories
bit-for-bit.  Multiplications by a traced f32 scalar are identical to
multiplications by the folded literal, and the one division in the hot
loop — the penalty term (I−Ẃ)x/α — is expressed as multiplication by
γ = float32(1)/float32(α), which is exactly what XLA's
division-by-literal folding computes (regression-tested against
inline legacy loops in tests/test_comm.py and tests/test_solve.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from . import _compat

METHODS = ("dagm", "dgbo", "dgtbo", "ma_dbo", "fednest")
TIERS = ("reference", "sharded", "serve")

#: Schedule field: constant, `repro.optim` schedule, or length-K tuple.
ScheduleLike = "float | Callable | tuple[float, ...] | None"


def _freeze_sequence(val):
    """Lists/arrays become tuples so specs stay hashable pytree leaves."""
    if isinstance(val, (list, np.ndarray)):
        return tuple(float(v) for v in np.asarray(val).ravel())
    return val


@dataclasses.dataclass(frozen=True)
class ScheduleSpec:
    """Runtime hyper-parameter sequences (per outer round k < K).

    alpha: outer step size αₖ.
    beta:  inner step size βₖ (also the inner penalty 1/βₖ).
    gamma: outer penalty coefficient γₖ multiplying (I−Ẃ)x in the
           Eq. (17b) hyper-gradient.  None (default) keeps the paper's
           coupling γₖ = 1/αₖ; an explicit schedule decouples a growing
           penalty from a decaying step size.
    """
    alpha: Any = 1e-2
    beta: Any = 1e-2
    gamma: Any = None

    def __post_init__(self):
        object.__setattr__(self, "alpha", _freeze_sequence(self.alpha))
        object.__setattr__(self, "beta", _freeze_sequence(self.beta))
        object.__setattr__(self, "gamma", _freeze_sequence(self.gamma))

    @property
    def is_constant(self) -> bool:
        return all(not callable(v) and not isinstance(v, tuple)
                   for v in (self.alpha, self.beta, self.gamma))

    def materialize(self, K: int) -> "RoundSchedules":
        """(K,) float32 arrays for α/β/γ (γ = f32(1)/f32(α) when None —
        the bit-exact twin of XLA's division-by-literal folding)."""
        alpha = _materialize_one(self.alpha, K, "alpha")
        beta = _materialize_one(self.beta, K, "beta")
        for name, arr in (("alpha", alpha), ("beta", beta)):
            if not np.all(arr > 0):
                raise ValueError(
                    f"ScheduleSpec.{name} must be positive at every "
                    f"round (min over K={K} rounds was {arr.min()!r}); "
                    f"step sizes of 0 or below stall/ diverge the run")
        if self.gamma is None:
            gamma = np.float32(1.0) / alpha
        else:
            gamma = _materialize_one(self.gamma, K, "gamma")
        return RoundSchedules(alpha=alpha, beta=beta, gamma=gamma)


def _materialize_one(val, K: int, name: str) -> np.ndarray:
    if callable(val):                       # repro.optim Schedule
        import jax.numpy as jnp
        arr = np.asarray(val(jnp.arange(K, dtype=jnp.int32)), np.float32)
        return np.broadcast_to(arr, (K,)).astype(np.float32)
    if isinstance(val, tuple):
        if len(val) != K:
            raise ValueError(
                f"ScheduleSpec.{name} has {len(val)} entries but the "
                f"run is K={K} rounds; pass one value per outer round "
                f"(or a float / repro.optim schedule)")
        return np.asarray(val, np.float32)
    return np.full((K,), np.float32(val), np.float32)


@dataclasses.dataclass(frozen=True)
class RoundSchedules:
    """Materialized (K,) float32 α/β/γ rows (host-side numpy)."""
    alpha: np.ndarray
    beta: np.ndarray
    gamma: np.ndarray

    def rows(self) -> np.ndarray:
        """(K, 3) stacked columns in (alpha, beta, gamma) order — the
        layout the serve tier stores per bucket slot."""
        return np.stack([self.alpha, self.beta, self.gamma], axis=1)

    @staticmethod
    def from_rows(rows: np.ndarray) -> "RoundSchedules":
        return RoundSchedules(alpha=rows[..., 0], beta=rows[..., 1],
                              gamma=rows[..., 2])


@dataclasses.dataclass(frozen=True)
class MixingSpec:
    """(I−W)·Y execution backend — see repro.topology.ops.MixingOp."""
    backend: str = "auto"       # "auto" | "dense" | "circulant[_pallas]"
    #                             | "sparse_gather[_pallas]"
    interpret: bool = True      # Pallas interpret mode (CPU)
    dtype: str = "f32"          # "f32" | "bf16" storage/gossip dtype


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """Gossip wire policy — see repro.comm.parse_comm_spec."""
    spec: str = "identity"      # "identity" | "bf16" | "int8[+ef]" | ...
    persist_ef: bool = False    # sharded tier: thread EF channel state
    #                             across outer rounds (ShardedDAGMConfig
    #                             .persist_ef semantics)


@dataclasses.dataclass(frozen=True)
class ShardedSpec:
    """Mesh wiring of the `distributed` tier (ignored elsewhere)."""
    axis: Any = "data"          # agent mesh axis (or tuple of axes)
    mix_every: int = 1          # gossip only every j-th inner step
    unroll_loops: bool = False  # Python-unroll M/U (dryrun accounting)


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """The single run description `repro.solve.solve` executes."""
    method: str = "dagm"        # METHODS
    tier: str = "reference"     # TIERS
    K: int = 100                # outer rounds
    M: int = 10                 # inner DGD steps per round
    U: int = 3                  # Neumann truncation order
    schedule: ScheduleSpec = ScheduleSpec()
    mixing: MixingSpec = MixingSpec()
    comm: CommSpec = CommSpec()
    sharded: ShardedSpec = ShardedSpec()
    dihgp: str = "dense"        # "dense" | "matrix_free" | "exact"
    curvature: float | None = None   # λmax bound for matrix_free
    momentum: float = 0.9       # ma_dbo tracker momentum
    b: int = 3                  # dgbo Hessian gossip rounds
    N: int = 5                  # dgtbo JHIP iterations
    faults: Any = None          # repro.faults.FaultSpec (or None): lower
    #                             a fault trace and run every gossip on
    #                             the per-round realized W_k

    # -- accounting conveniences (mirror the DAGMConfig API) ---------------

    def comm_channels(self, d1: int, d2: int) -> list[tuple]:
        h_sends = 0 if self.dihgp == "exact" else self.U
        return [("inner_y", (d2,), self.M),
                ("dihgp_h", (d2,), h_sends),
                ("outer_x", (d1,), 1)]

    def comm_ledger(self, d1: int, d2: int, rounds: int | None = None):
        from repro.comm import static_ledger
        K = self.K if rounds is None else rounds
        return static_ledger(
            self.comm.spec,
            [(name, shape, K * sends) for name, shape, sends
             in self.comm_channels(d1, d2)], name="dagm")


def validate_spec(spec: "SolverSpec") -> None:
    """Reject inexpressible/conflicting specs with actionable messages.

    Shared by `solve()` and the serve tier's `compile_signature` (every
    job is validated before it can mint a bucket)."""
    if spec.method not in METHODS:
        raise ValueError(
            f"unknown method {spec.method!r}; expected one of {METHODS}")
    if spec.tier not in TIERS:
        raise ValueError(
            f"unknown tier {spec.tier!r}; expected one of {TIERS}")
    for name, val in (("K", spec.K), ("M", spec.M), ("b", spec.b),
                      ("N", spec.N)):
        if int(val) <= 0:
            raise ValueError(
                f"SolverSpec.{name} must be a positive iteration count "
                f"(got {val}); 0 rounds is not a run — drop the phase "
                f"by choosing a method/dihgp that skips it instead")
    if int(spec.U) < 0:
        raise ValueError(
            f"SolverSpec.U must be a non-negative Neumann truncation "
            f"order (got {spec.U}); U=0 keeps only the D̃⁻¹ "
            f"preconditioner term")
    # materialization validates schedule lengths + positivity
    spec.schedule.materialize(spec.K)
    if spec.tier in ("sharded", "serve") and spec.method != "dagm":
        raise ValueError(
            f"tier={spec.tier!r} only executes method='dagm' (the "
            f"baselines exist for reference-tier comparison); got "
            f"method={spec.method!r} — use tier='reference'")
    if spec.schedule.gamma is not None and \
            spec.method in ("dgbo", "dgtbo", "fednest"):
        raise ValueError(
            f"method={spec.method!r} has no penalty term: the gamma "
            f"schedule multiplies DAGM's (I−Ŵ)x/α "
            f"penalty gradient, which this baseline never forms; drop "
            f"schedule.gamma or use method='dagm'/'ma_dbo'")
    if spec.schedule.gamma is not None and spec.tier == "sharded":
        raise ValueError(
            "the sharded tier folds the penalty coefficient into the "
            "Ŵx − α(·) update (α·γ "
            "= 1 by construction), so an explicit gamma schedule is "
            "inexpressible there; use tier='reference' for decoupled "
            "penalties")
    if spec.comm.persist_ef and spec.tier != "sharded":
        raise ValueError(
            f"CommSpec.persist_ef=True is a sharded-tier knob (the "
            f"reference and serve tiers already thread channel state "
            f"through the whole run); got tier={spec.tier!r}")
    if spec.comm.persist_ef and spec.comm.spec == "identity":
        raise ValueError(
            "CommSpec.persist_ef=True with spec='identity' conflicts: "
            "the identity wire has no error-feedback state to persist; "
            "pick a compressing spec (e.g. 'top_k:0.1+ef') or drop "
            "persist_ef")
    if spec.comm.spec != "identity" and spec.dihgp == "exact":
        raise ValueError(
            "dihgp='exact' solves the penalized system densely and has "
            "no gossip to compress; use 'dense' or 'matrix_free' with "
            f"comm={spec.comm.spec!r}")
    if spec.faults is not None:
        from repro.faults import FaultSpec
        if not isinstance(spec.faults, FaultSpec):
            raise ValueError(
                f"SolverSpec.faults must be a repro.faults.FaultSpec "
                f"(got {type(spec.faults).__name__}); construct one "
                f"with FaultSpec(drop_prob=..., stragglers=..., "
                f"churn=..., seed=...)")
        if spec.method != "dagm":
            raise ValueError(
                f"fault injection degrades the DAGM gossip rounds; the "
                f"baseline methods do not thread per-round edge masks "
                f"(got method={spec.method!r}) — use method='dagm' or "
                f"drop SolverSpec.faults")
        if spec.tier != "reference":
            raise ValueError(
                f"fault-masked mixing is a reference-tier feature (got "
                f"tier={spec.tier!r}): serve buckets share one compiled "
                f"program whose per-slot operands are hyper-parameters "
                f"only, and the sharded tier's lax.ppermute gossip has "
                f"no per-round mask channel yet — use tier='reference'")
    if spec.tier == "sharded" and spec.curvature is None:
        raise ValueError(
            "the sharded tier's scalar-preconditioned DIHGP needs an "
            "explicit curvature bound (SolverSpec.curvature ≥ "
            "λmax(∇²_y g_i)); there is no power-"
            "iteration fallback inside shard_map")


# ---------------------------------------------------------------------------
# Lowering from the legacy config surfaces
# ---------------------------------------------------------------------------

def as_solver_spec(cfg) -> "SolverSpec":
    """Normalize any config surface to a SolverSpec.

    Accepts a SolverSpec (returned as-is), a `DAGMConfig` or a
    `ShardedDAGMConfig` (lowered field-by-field — the deprecation
    warning fired when the caller constructed the legacy object, so
    lowering itself is silent)."""
    if isinstance(cfg, SolverSpec):
        return cfg
    from repro.core.dagm import DAGMConfig
    from repro.distributed.dagm_sharded import ShardedDAGMConfig
    if isinstance(cfg, DAGMConfig):
        return SolverSpec(
            method="dagm", tier="reference", K=cfg.K, M=cfg.M, U=cfg.U,
            schedule=ScheduleSpec(alpha=cfg.alpha, beta=cfg.beta),
            mixing=MixingSpec(backend=cfg.mixing,
                              interpret=cfg.mixing_interpret,
                              dtype=cfg.mixing_dtype),
            comm=CommSpec(spec=cfg.comm),
            dihgp=cfg.dihgp, curvature=cfg.curvature)
    if isinstance(cfg, ShardedDAGMConfig):
        comm = cfg.comm
        if comm == "identity" and cfg.comm_dtype == "bf16":
            comm = "bf16"             # legacy comm_dtype alias
        return SolverSpec(
            method="dagm", tier="sharded", K=1, M=cfg.M, U=cfg.U,
            schedule=ScheduleSpec(alpha=cfg.alpha, beta=cfg.beta),
            mixing=MixingSpec(dtype=cfg.comm_dtype),
            comm=CommSpec(spec=comm, persist_ef=cfg.persist_ef),
            sharded=ShardedSpec(axis=cfg.axis, mix_every=cfg.mix_every,
                                unroll_loops=cfg.unroll_loops),
            dihgp="matrix_free", curvature=cfg.curvature)
    raise TypeError(
        f"expected SolverSpec, DAGMConfig or ShardedDAGMConfig, got "
        f"{type(cfg).__name__}")


def mixing_kwargs(cfg) -> dict:
    """`make_mixing_op` kwargs from any config surface."""
    spec = as_solver_spec(cfg)
    return dict(backend=spec.mixing.backend,
                interpret=spec.mixing.interpret,
                dtype=spec.mixing.dtype, comm=spec.comm.spec)


def dagm_spec(alpha=1e-2, beta=1e-2, gamma=None, K: int = 100,
              M: int = 10, U: int = 3, dihgp: str = "dense",
              curvature: float | None = None, mixing: str = "auto",
              mixing_interpret: bool = True, mixing_dtype: str = "f32",
              comm: str = "identity", tier: str = "reference",
              faults=None) -> SolverSpec:
    """Convenience constructor mirroring the old DAGMConfig kwargs —
    the one-line migration target for `DAGMConfig(...)` call sites."""
    return SolverSpec(
        method="dagm", tier=tier, K=K, M=M, U=U,
        schedule=ScheduleSpec(alpha=alpha, beta=beta, gamma=gamma),
        mixing=MixingSpec(backend=mixing, interpret=mixing_interpret,
                          dtype=mixing_dtype),
        comm=CommSpec(spec=comm), dihgp=dihgp, curvature=curvature,
        faults=faults)


def sharded_spec(alpha=1e-2, beta=1e-2, M: int = 5, U: int = 3,
                 curvature: float = 4.0, axis="data",
                 comm: str = "identity", comm_dtype: str = "f32",
                 persist_ef: bool = False, mix_every: int = 1,
                 unroll_loops: bool = False, K: int = 1) -> SolverSpec:
    """Convenience constructor mirroring the old ShardedDAGMConfig
    kwargs (K is the round budget when driven through `solve`; the raw
    `make_sharded_dagm` step is still one round per call)."""
    if comm == "identity" and comm_dtype == "bf16":
        comm = "bf16"
    return SolverSpec(
        method="dagm", tier="sharded", K=K, M=M, U=U,
        schedule=ScheduleSpec(alpha=alpha, beta=beta),
        mixing=MixingSpec(dtype=comm_dtype),
        comm=CommSpec(spec=comm, persist_ef=persist_ef),
        sharded=ShardedSpec(axis=axis, mix_every=mix_every,
                            unroll_loops=unroll_loops),
        dihgp="matrix_free", curvature=curvature)


def _register_static(cls):
    import jax
    jax.tree_util.register_static(cls)
    return cls


for _cls in (ScheduleSpec, MixingSpec, CommSpec, ShardedSpec,
             SolverSpec):
    _register_static(_cls)

# re-export for shim modules
silently = _compat.silently
warn_once = _compat.warn_once
