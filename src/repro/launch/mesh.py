"""Production mesh factory.

Single pod: (data=16, model=16) = 256 chips (TPU v5e-256 slice).
Multi-pod: (pod=2, data=16, model=16) = 512 chips, where the "pod" axis
crosses the DCN/ICI boundary.  Defined as a *function* so importing this
module never touches jax device state (the dry-run sets
--xla_force_host_platform_device_count=512 before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


# Hardware constants for the roofline analysis (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link
