"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes, with NO device allocation (ShapeDtypeStruct
stand-ins), and extract the roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json

The os.environ line below MUST run before ANY jax import (including
transitively via repro.*): jax locks the device count on first init.
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import re
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, INPUT_SHAPES, get_config
from repro.configs.base import ArchConfig, InputShape
from repro.distributed.sharding import (make_rules, tree_param_sharding,
                                        use_rules)
from repro.launch.costs import (affine_correct, depth_pair,
                                flops_estimate, model_flops_convention,
                                reduced_depth)
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models import build_model
from repro.models.steps import make_decode_step, make_prefill_step, \
    make_train_step
from repro.optim import adamw
from jax.sharding import NamedSharding, PartitionSpec as P

# long_500k policy (DESIGN.md §5): whisper skipped; SSM/hybrid native;
# attention archs use a sliding-window cache of this size:
LONG_WINDOW = 8192
SKIP = {("whisper-large-v3", "long_500k"):
        "encoder-decoder: 500k self-cache is semantically undefined "
        "(30s audio source); see DESIGN.md §5"}

COMPUTE_DTYPE = jnp.bfloat16


def microbatches_for(cfg: ArchConfig, shape: InputShape, mesh) -> int:
    """Grad-accumulation factor so remat'd activations fit HBM:
    saved bytes ≈ L × B_shard/mb × S × d × 2; target ≤ 2 GB."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = axes.get("data", 1) * axes.get("pod", 1)
    b_shard = max(shape.global_batch // dp, 1)
    layers = cfg.num_layers + cfg.encoder_layers
    bytes_act = layers * b_shard * shape.seq_len * cfg.d_model * 2
    mb = 1
    while bytes_act / mb > 2e9 and mb < b_shard:
        mb *= 2
    return mb


def batch_specs(cfg: ArchConfig, shape: InputShape, *, with_labels: bool):
    B, S = shape.global_batch, shape.seq_len
    spec = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    sh = {"tokens": ("batch", None)}
    if with_labels:
        spec["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        sh["labels"] = ("batch", None)
    if cfg.encoder_decoder:
        spec["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_frames, cfg.d_model), COMPUTE_DTYPE)
        sh["frames"] = ("batch", None, None)
    return spec, sh


def cache_logical_axes(cfg: ArchConfig, cache_shapes):
    """Logical axes for every cache leaf, matched by key path."""
    def leaf_axes(path, leaf):
        keys = [getattr(p, "key", str(p)) for p in path]
        name = keys[-1]
        nd = len(leaf.shape)
        if "xkv" in keys:                  # (L, B, frames, Hkv, hd)
            return (None, "batch", None, "kv_heads", None)
        if name in ("k", "v"):             # (L|n_inv, B, C, Hkv, hd)
            return (None, "batch", "cache_seq", "kv_heads", None)
        if name == "S" and cfg.attn_free:  # (L, B, H, hd, hd)
            return (None, "batch", "rwkv_heads", None, None)
        if name == "S":                    # mamba (L, B, H, hd, N)
            return (None, "batch", "ffn", None, None)
        if name == "conv":                 # (L, B, K-1, d_inner)
            return (None, "batch", None, "ffn")
        if name in ("tm_x", "cm_x"):       # (L, B, d)
            return (None, "batch", None)
        if name == "pos":
            return ()
        return tuple([None] * nd)
    return jax.tree_util.tree_map_with_path(leaf_axes, cache_shapes)


def input_specs(arch: str, shape_name: str):
    """Public API: ShapeDtypeStruct stand-ins for every model input of
    the given (arch × shape) combination."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return batch_specs(cfg, shape, with_labels=True)[0]
    if shape.kind == "prefill":
        return batch_specs(cfg, shape, with_labels=False)[0]
    # decode: one new token + cache of seq_len
    model = build_model(cfg)
    window = LONG_WINDOW if (shape_name == "long_500k"
                             and not cfg.sliding_window
                             and not cfg.attn_free
                             and not cfg.shared_attn_every) else 0
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 COMPUTE_DTYPE, window_override=window))
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return {"tokens": tokens, "cache": cache}


@dataclasses.dataclass
class DryRunResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    error: str = ""
    skip_reason: str = ""
    compile_s: float = 0.0
    flops: float = 0.0
    hbm_bytes_accessed: float = 0.0
    peak_memory_per_device: float = 0.0
    argument_size_per_device: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    params_b: float = 0.0
    microbatches: int = 1
    # scan-corrected accounting (unrolled depth-pair extrapolation)
    flops_corrected: float = 0.0
    bytes_corrected: float = 0.0
    collective_bytes_corrected: float = 0.0
    analytic_flops_per_chip: float = 0.0
    model_flops_per_chip: float = 0.0
    useful_ratio: float = 0.0

    def roofline(self) -> dict:
        """Roofline terms in seconds.  compiled.cost_analysis() and the
        partitioned HLO are already PER-DEVICE quantities (the executable
        is the per-chip SPMD program), so no further division by chip
        count — verified against 2·N·B hand counts in tests.  Corrected
        values (scan-aware) are used when the accounting pass ran."""
        coll = self.collective_bytes_corrected or \
            sum(self.collective_bytes.values())
        flops = self.flops_corrected or self.flops
        byts = self.bytes_corrected or self.hbm_bytes_accessed
        terms = {
            "compute_s": flops / PEAK_FLOPS_BF16,
            "memory_s": byts / HBM_BW,
            "collective_s": coll / ICI_BW,
        }
        terms["bottleneck"] = max(terms, key=terms.get)
        return terms


_COLL_RE = re.compile(
    r"(\S+)\s*=\s*(?:\(.*?\)|\S+)\s*(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)")


def collective_bytes_from_hlo(hlo: str) -> dict[str, float]:
    """Sum result sizes of every collective op in the (per-device) HLO.

    Async `-start` ops carry tuple result types (operand alias +
    result); all tuple elements are counted, so async collectives are
    counted once at `-start` (the `-done` line is skipped)."""
    sizes: dict[str, float] = {}
    shape_re = re.compile(
        r"(bf16|f32|f16|s32|u32|s8|u8|f64|pred)\[([\d,]*)\]")
    bytes_of = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "f64": 8, "pred": 1}
    for line in hlo.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        m = re.search(r"(all-gather|all-reduce|reduce-scatter|"
                      r"all-to-all|collective-permute)(-start|-done)?\(",
                      line)
        if not m or m.group(2) == "-done":
            continue
        op = m.group(1)
        region = line[line.index("=") + 1:m.start(1)]   # result type(s)
        total = 0
        for dt, dims in shape_re.findall(region):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * bytes_of[dt]
        sizes[op] = sizes.get(op, 0) + total
    return sizes


def build_step_and_args(cfg: ArchConfig, shape: InputShape, mesh, rules,
                        *, unroll: bool = False,
                        microbatches: int | None = None):
    """Returns (fn, arg_shapes, in_shardings)."""
    model = build_model(cfg)
    axes = model.param_axes()
    param_sh = tree_param_sharding(axes, rules)
    params_shape = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), COMPUTE_DTYPE))

    def named(*logical):
        return NamedSharding(mesh, rules.resolve(*logical))

    if shape.kind == "train":
        opt = adamw(1e-4)
        mb = microbatches if microbatches else \
            microbatches_for(cfg, shape, mesh)
        step = make_train_step(model, opt, microbatches=mb,
                               unroll=unroll)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        # opt state mirrors param shardings for mu/nu; step replicated
        opt_sh = type(opt_shape)(step=named(), mu=param_sh, nu=param_sh)
        bspec, bsh = batch_specs(cfg, shape, with_labels=True)
        batch_sh = {k: named(*v) for k, v in bsh.items()}
        return (step, (params_shape, opt_shape, bspec),
                (param_sh, opt_sh, batch_sh),
                {"microbatches": mb, "donate": (0, 1)})

    if shape.kind == "prefill":
        fn = make_prefill_step(model, cache_dtype=COMPUTE_DTYPE,
                               unroll=unroll)
        bspec, bsh = batch_specs(cfg, shape, with_labels=False)
        batch_sh = {k: named(*v) for k, v in bsh.items()}
        return fn, (params_shape, bspec), (param_sh, batch_sh), {}

    # decode
    fn = make_decode_step(model, unroll=unroll)
    window = LONG_WINDOW if (shape.name == "long_500k"
                             and not cfg.sliding_window
                             and not cfg.attn_free
                             and not cfg.shared_attn_every) else 0
    model_ic = build_model(cfg)
    cache_shape = jax.eval_shape(
        lambda: model_ic.init_cache(shape.global_batch, shape.seq_len,
                                    COMPUTE_DTYPE, window_override=window))
    cache_ax = cache_logical_axes(cfg, cache_shape)
    cache_sh = jax.tree.map(lambda a: named(*a), cache_ax,
                            is_leaf=lambda t: isinstance(t, tuple))
    tok_shape = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tok_sh = named("batch", None)
    return (fn, (params_shape, tok_shape, cache_shape),
            (param_sh, tok_sh, cache_sh), {"donate": (2,)})


def _compile_once(cfg, shape, mesh, rules, *, unroll=False,
                  microbatches=None):
    fn, args, shardings, extra = build_step_and_args(
        cfg, shape, mesh, rules, unroll=unroll, microbatches=microbatches)
    lowered = jax.jit(fn, in_shardings=shardings,
                      donate_argnums=extra.get("donate", ())).lower(*args)
    return lowered.compile(), extra


def accounting_pass(cfg, shape, mesh, rules, res: DryRunResult):
    """Two unrolled reduced-depth compiles → affine-in-L corrected
    flops / bytes / collective bytes (see launch/costs.py)."""
    l1, l2 = depth_pair(cfg)
    vals = {}
    for L in (l1, l2):
        c, _ = _compile_once(reduced_depth(cfg, L), shape, mesh, rules,
                             unroll=True, microbatches=1)
        cost = c.cost_analysis()
        vals[L] = (float(cost.get("flops", 0.0)),
                   float(cost.get("bytes accessed", 0.0)),
                   sum(collective_bytes_from_hlo(c.as_text()).values()))
    L = cfg.num_layers
    res.flops_corrected = affine_correct(vals[l1][0], vals[l2][0], l1, l2, L)
    res.bytes_corrected = affine_correct(vals[l1][1], vals[l2][1], l1, l2, L)
    res.collective_bytes_corrected = affine_correct(
        vals[l1][2], vals[l2][2], l1, l2, L)
    n_chips = int(np.prod(mesh.devices.shape))
    res.analytic_flops_per_chip = flops_estimate(cfg, shape) / n_chips
    model = build_model(cfg)
    n_active = int(model.param_count() *
                   (get_config(res.arch).active_param_count()
                    / max(get_config(res.arch).param_count(), 1)))
    res.model_flops_per_chip = model_flops_convention(
        cfg, shape, n_active) / n_chips
    if res.flops_corrected:
        res.useful_ratio = res.model_flops_per_chip / res.flops_corrected


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            verbose: bool = True, accounting: bool = False,
            moe_groups: int = 0, expert_parallel: bool = False,
            moe_impl: str = "batched", microbatches: int = 0
            ) -> DryRunResult:
    cfg = get_config(arch)
    if moe_groups and cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_route_groups=moe_groups,
                                  moe_group_impl=moe_impl)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    res = DryRunResult(arch=arch, shape=shape_name, mesh=mesh_name,
                       ok=False)
    if (arch, shape_name) in SKIP:
        res.skip_reason = SKIP[(arch, shape_name)]
        if verbose:
            print(f"[dryrun] SKIP {arch} × {shape_name}: {res.skip_reason}")
        return res

    # batch=1 decode cannot shard the batch axis; shard cache seq instead
    seq_cache = shape.kind == "decode"
    fsdp = shape.kind == "train"
    rules = make_rules(cfg, mesh, seq_shard_cache=seq_cache, fsdp=fsdp,
                       expert_parallel=expert_parallel)
    if shape.global_batch == 1:
        # batch=1 cannot shard over data: re-lay the cache sequence over
        # the freed axes instead (minus any axis kv_heads already owns).
        cs = "data" if rules.table.get("kv_heads") else ("data", "model")
        rules = dataclasses.replace(
            rules, table={**rules.table, "batch": None, "cache_seq": cs})

    t0 = time.time()
    try:
        with use_rules(rules):
            compiled, extra = _compile_once(
                cfg, shape, mesh, rules,
                microbatches=microbatches or None)
        res.compile_s = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        res.flops = float(cost.get("flops", 0.0))
        res.hbm_bytes_accessed = float(cost.get("bytes accessed", 0.0))
        res.peak_memory_per_device = float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0))
        res.argument_size_per_device = float(
            getattr(mem, "argument_size_in_bytes", 0))
        res.collective_bytes = collective_bytes_from_hlo(
            compiled.as_text())
        res.params_b = build_model(cfg).param_count() / 1e9
        res.microbatches = extra.get("microbatches", 1)
        if accounting:
            with use_rules(rules):
                accounting_pass(cfg, shape, mesh, rules, res)
        res.ok = True
        if verbose:
            rf = res.roofline()
            terms = {k: f'{v*1e3:.2f}ms' for k, v in rf.items()
                     if k != 'bottleneck'}
            print(f"[dryrun] OK {arch} × {shape_name} ({mesh_name}) "
                  f"compile={res.compile_s:.1f}s flops={res.flops:.3g} "
                  f"corr={res.flops_corrected:.3g} "
                  f"mem/dev={res.peak_memory_per_device/1e9:.2f}GB "
                  f"coll={sum(res.collective_bytes.values())/1e9:.3f}GB "
                  f"roofline={terms} bound={rf['bottleneck']}")
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        res.error = f"{type(e).__name__}: {e}"
        res.compile_s = time.time() - t0
        if verbose:
            print(f"[dryrun] FAIL {arch} × {shape_name}: {res.error[:500]}")
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--accounting", action="store_true",
                    help="also run the unrolled cost-accounting compiles")
    ap.add_argument("--moe-groups", type=int, default=0,
                    help="group-local MoE routing domains (§Perf variant;"
                         " 0 = paper-faithful global routing)")
    ap.add_argument("--expert-parallel", action="store_true",
                    help="experts over the model axis (§Perf variant)")
    ap.add_argument("--moe-impl", default="batched",
                    choices=["batched", "shard_map"])
    ap.add_argument("--microbatches", type=int, default=0,
                    help="override the grad-accumulation heuristic "
                         "(train shapes; §Perf-1 iter 6)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    kw = dict(multi_pod=args.multi_pod, accounting=args.accounting,
              moe_groups=args.moe_groups,
              expert_parallel=args.expert_parallel,
              moe_impl=args.moe_impl, microbatches=args.microbatches)
    results = []
    if args.all:
        for arch in ARCHS:
            for shape in INPUT_SHAPES:
                results.append(run_one(arch, shape, **kw))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        results.append(run_one(args.arch, args.shape, **kw))
    if args.out:
        with open(args.out, "w") as f:
            json.dump([dataclasses.asdict(r) for r in results], f,
                      indent=1)
    n_fail = sum(1 for r in results if not r.ok and not r.skip_reason)
    print(f"[dryrun] {sum(r.ok for r in results)} ok, {n_fail} failed, "
          f"{sum(1 for r in results if r.skip_reason)} skipped")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
