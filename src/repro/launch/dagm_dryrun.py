"""Production-mesh dry-run of the paper's own technique: one full DAGM
outer round (Algorithm 2 — M inner DGD steps, DIHGP, outer step) for the
decentralized bilevel loss-weight-tuning problem, with the inner variable
y = a full assigned-architecture LM, lowered + compiled on the 16×16
(or 2×16×16) mesh with no allocation.

Layout: agents = the "data" mesh axis (16 agents single-pod) or the
flattened ("pod", "data") product (32 agents multi-pod, two ring edges
crossing the pod boundary), on a Metropolis ring; tensor parallelism
over "model" *inside* each agent (shard_map auto axes).  All cross-agent
traffic is `lax.ppermute` of parameter-pytree vectors — the paper's
vector-communication pattern at pod scale.

    PYTHONPATH=src python -m repro.launch.dagm_dryrun --arch qwen3-4b \
        [--multi-pod] [--seq-len 4096] [--batch-per-agent 16] [--bf16-comm]

This is the §Perf "most representative of the paper's technique" lane:
the baseline is the paper-faithful f32 ring exchange; --bf16-comm and
--local-updates are the beyond-paper variants recorded separately in
EXPERIMENTS.md.
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.distributed.dagm_sharded import make_sharded_dagm
from repro.solve import sharded_spec
from repro.distributed.sharding import make_rules
from repro.launch.dryrun import collective_bytes_from_hlo
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models import build_model

N_DOMAINS = 8


def build_dagm_bilevel(cfg, *, seq_len: int, batch_per_agent: int,
                       dcfg):
    """Per-agent bilevel objectives for decentralized loss-weight tuning
    (same formulation as examples/train_lm_dagm.py, dry-run sized)."""
    from repro.models import transformer as tf

    D = N_DOMAINS

    def weighted_ce(x, y, batch, weighted: bool):
        logits, _ = tf.forward(y, cfg, batch["tokens"], remat=True)
        V = logits.shape[-1]
        lse = jax.nn.logsumexp(
            jnp.where(jnp.arange(V) >= cfg.vocab_size, -1e30,
                      logits.astype(jnp.float32)), axis=-1)
        true = jnp.take_along_axis(
            logits.astype(jnp.float32), batch["labels"][..., None],
            axis=-1)[..., 0]
        ce = lse - true
        if weighted:
            wdom = jax.nn.softmax(x[:D])[batch["domain"]]
            ce = ce * wdom[:, None] * D
        return jnp.mean(ce)

    def g_fn(x, y, batch):
        wd = 1e-5 * jnp.exp(jnp.clip(x[D], -3.0, 3.0))
        l2 = sum(jnp.sum(jnp.square(p.astype(jnp.float32)))
                 for p in jax.tree.leaves(y))
        return weighted_ce(x, y, batch["train"], True) + 0.5 * wd * l2

    def f_fn(x, y, batch):
        return weighted_ce(x, y, batch["val"], False)

    return g_fn, f_fn


def batch_shapes(cfg, n_agents: int, seq_len: int, batch_per_agent: int):
    B, S = batch_per_agent, seq_len
    one = {"tokens": jax.ShapeDtypeStruct((n_agents, B, S), jnp.int32),
           "labels": jax.ShapeDtypeStruct((n_agents, B, S), jnp.int32),
           "domain": jax.ShapeDtypeStruct((n_agents, B), jnp.int32)}
    return {"train": one, "val": dict(one)}


def run(arch: str, *, multi_pod: bool = False, seq_len: int = 4096,
        batch_per_agent: int = 16, M: int = 2, U: int = 3,
        comm_dtype: str = "f32", param_dtype: str = "f32",
        mix_every: int = 1, verbose: bool = True) -> dict:
    COMPUTE_DTYPE = jnp.bfloat16 if param_dtype == "bf16" else jnp.float32
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_agents = axes.get("data", 1) * axes.get("pod", 1)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    # multi-pod: one 32-agent ring across ("pod", "data") — the ring is
    # laid out so consecutive agents are ICI neighbors and exactly two
    # edges cross the pod boundary (DESIGN.md §2)
    agent_axis = ("pod", "data") if multi_pod else "data"
    dcfg = sharded_spec(alpha=0.3, beta=0.1, M=M, U=U,
                        curvature=8.0, axis=agent_axis,
                        comm_dtype=comm_dtype, mix_every=mix_every,
                        unroll_loops=True)
    g_fn, f_fn = build_dagm_bilevel(cfg, seq_len=seq_len,
                                    batch_per_agent=batch_per_agent,
                                    dcfg=dcfg)

    model = build_model(cfg)
    rules = make_rules(cfg, mesh, fsdp=False)
    # params per agent: logical axes -> P with leading agent ("data") axis
    param_axes = model.param_axes()
    agent_ax0 = ("pod", "data") if multi_pod else "data"
    y_sharding = jax.tree.map(
        lambda ax_: NamedSharding(
            mesh, P(agent_ax0, *[rules.table.get(a) for a in ax_])),
        param_axes, is_leaf=lambda t: isinstance(t, tuple))
    y_spec = jax.tree.map(lambda s: P("data"), y_sharding)

    # Agents = the ring over the agent axis: 16 single-pod, 32 across
    # ("pod", "data") multi-pod.
    n_ring = axes["data"] * (axes.get("pod", 1) if multi_pod else 1)
    y_shape = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n_ring,) + l.shape, COMPUTE_DTYPE),
        jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0),
                                          COMPUTE_DTYPE)))
    x_shape = jax.ShapeDtypeStruct((n_ring, N_DOMAINS + 1), jnp.float32)
    bshape = batch_shapes(cfg, n_ring, seq_len, batch_per_agent)

    manual = {"pod", "data"} if multi_pod else {"data"}
    step, _ = make_sharded_dagm(g_fn, f_fn, dcfg, mesh,
                                manual_axes=manual, jit_step=False)

    x_sh = NamedSharding(mesh, P(agent_axis))
    b_sh = jax.tree.map(lambda _: NamedSharding(mesh, P(agent_axis)),
                        bshape)

    t0 = time.time()
    # NOTE: rules are used only to build the boundary in_shardings; the
    # model's internal shard() constraints must stay OFF inside the
    # shard_map manual region (their NamedShardings carry the fully-Auto
    # mesh and clash with the Manual context) — GSPMD propagates the
    # model-axis layout from the parameter shardings instead.
    lowered = jax.jit(step,
                      in_shardings=(x_sh, y_sharding, b_sh),
                      donate_argnums=(0, 1)).lower(
        x_shape, y_shape, bshape)
    compiled = lowered.compile()
    compile_s = time.time() - t0

    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    coll = collective_bytes_from_hlo(compiled.as_text())
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    peak = float(getattr(mem, "temp_size_in_bytes", 0)
                 + getattr(mem, "argument_size_in_bytes", 0)
                 + getattr(mem, "output_size_in_bytes", 0)
                 - getattr(mem, "alias_size_in_bytes", 0))
    terms = {"compute_s": flops / PEAK_FLOPS_BF16,
             "memory_s": byts / HBM_BW,
             "collective_s": sum(coll.values()) / ICI_BW}
    bound = max(terms, key=terms.get)
    out = {"arch": arch, "mesh": mesh_name, "M": M, "U": U,
           "comm_dtype": comm_dtype, "param_dtype": param_dtype,
           "mix_every": mix_every, "seq_len": seq_len,
           "batch_per_agent": batch_per_agent,
           "compile_s": compile_s, "flops": flops, "bytes": byts,
           "peak_memory_per_device": peak,
           "collective_bytes": coll, "roofline": terms,
           "bottleneck": bound}
    if verbose:
        t = {k: f"{v*1e3:.2f}ms" for k, v in terms.items()}
        print(f"[dagm-dryrun] OK {arch} ({mesh_name}) M={M} U={U} "
              f"comm={comm_dtype} compile={compile_s:.1f}s "
              f"mem/dev={peak/1e9:.2f}GB "
              f"coll={sum(coll.values())/1e9:.3f}GB roofline={t} "
              f"bound={bound}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seq-len", type=int, default=4096)
    ap.add_argument("--batch-per-agent", type=int, default=16)
    ap.add_argument("--inner-steps", type=int, default=2)
    ap.add_argument("--neumann-u", type=int, default=3)
    ap.add_argument("--comm-dtype", default="f32",
                    choices=["f32", "bf16"])
    ap.add_argument("--param-dtype", default="f32",
                    choices=["f32", "bf16"])
    ap.add_argument("--mix-every", type=int, default=1)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    res = run(args.arch, multi_pod=args.multi_pod, seq_len=args.seq_len,
              batch_per_agent=args.batch_per_agent, M=args.inner_steps,
              U=args.neumann_u, comm_dtype=args.comm_dtype,
              param_dtype=args.param_dtype, mix_every=args.mix_every)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
