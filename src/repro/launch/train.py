"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b \
        --smoke --steps 20 --seq-len 128 --global-batch 8

Builds the mesh over available devices, applies the sharding rules,
streams the synthetic token pipeline, runs the jitted train step with
checkpointing and logging.  `--smoke` swaps in the reduced config so the
same driver runs on CPU; on a real TPU slice drop `--smoke` and point
`--mesh` at the slice shape.  `--dagm` switches the optimizer from
AdamW data-parallelism to the paper's decentralized bilevel trainer
(see examples/train_lm_dagm.py for the bilevel formulation).
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_checkpoint, \
    save_checkpoint
from repro.configs import get_config
from repro.data import TokenDataConfig, make_token_batch
from repro.distributed.sharding import make_rules, tree_param_sharding, \
    use_rules
from repro.models import build_model
from repro.models.steps import make_train_step
from repro.optim import adamw, cosine_schedule
from jax.sharding import NamedSharding


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev // args.model_parallel,
                          args.model_parallel), ("data", "model"))
    rules = make_rules(cfg, mesh)
    print(f"[train] {cfg.name}: {model.param_count()/1e6:.1f}M params, "
          f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    opt = adamw(cosine_schedule(args.lr, args.warmup, args.steps))
    data_cfg = TokenDataConfig(vocab_size=cfg.vocab_size,
                               seq_len=args.seq_len,
                               global_batch=args.global_batch,
                               seed=args.seed)

    with use_rules(rules):
        params = model.init(jax.random.PRNGKey(args.seed))
        opt_state = opt.init(params)
        param_sh = tree_param_sharding(model.param_axes(), rules)
        params = jax.device_put(params, param_sh)
        step_fn = jax.jit(make_train_step(
            model, opt, microbatches=args.microbatches))

        start = 0
        if args.ckpt_dir and (s := latest_step(args.ckpt_dir)) is not None:
            params = restore_checkpoint(args.ckpt_dir, s, params)
            start = s
            print(f"[train] restored step {s}")

        losses = []
        t0 = time.time()
        for step in range(start, args.steps):
            batch = make_token_batch(data_cfg, step)
            if cfg.encoder_decoder:
                batch["frames"] = 0.02 * jax.random.normal(
                    jax.random.PRNGKey(step),
                    (args.global_batch, cfg.encoder_frames, cfg.d_model))
            batch = {k: jax.device_put(
                v, NamedSharding(mesh, rules.resolve(
                    "batch", *([None] * (v.ndim - 1)))))
                for k, v in batch.items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                      f"({dt:.1f}s)")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, step + 1, params)
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, args.steps, params)
        improved = losses[-1] < losses[0]
        print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"(improved={improved})")
        return 0 if np.isfinite(losses[-1]) else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
