"""Analytic cost model + scan-corrected HLO accounting.

XLA's `compiled.cost_analysis()` counts a While-loop body ONCE, so any
step built on scan-over-layers (or grad-accumulation scan) under-reports
flops/bytes by ~L (measured: qwen3 train shows 1.8e12 vs ~1.1e14
expected).  Two complementary fixes, both reported in §Roofline:

1. `flops_estimate` — hand cost model per architecture (projections,
   quadratic attention with causality/windowing, MoE active experts,
   recurrence updates).  MODEL_FLOPS = 6·N·D / 2·N·D convention also
   provided for the "useful compute" ratio.

2. `affine_correct` — compile *unrolled* reduced-depth variants
   (L ∈ {2, 4}, microbatches=1) of the same (arch × shape); every cost
   is affine in L (out-of-loop a + per-layer b), so
   cost(L_full) = a + L_full·b.  The remaining undercount is the inner
   time-scan of RWKV/Mamba state updates, which is < 1 % of their layer
   flops (projections dominate) — noted, not corrected.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, InputShape


def _attn_flops_per_token(cfg: ArchConfig, ctx: float) -> float:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    proj = 2 * d * (cfg.q_dim * 2 + cfg.kv_dim * 2)
    sdpa = 4 * ctx * cfg.num_heads * hd
    return proj + sdpa


def _ffn_flops_per_token(cfg: ArchConfig) -> float:
    if cfg.num_experts:
        router = 2 * cfg.d_model * cfg.num_experts
        return router + cfg.top_k * 6 * cfg.d_model * cfg.d_ff
    return 6 * cfg.d_model * cfg.d_ff


def _rwkv_flops_per_token(cfg: ArchConfig) -> float:
    d = cfg.d_model
    time_mix = 10 * d * d + 6 * cfg.rwkv_head_size * d + 2 * d * d
    channel_mix = 4 * d * cfg.d_ff + 2 * d * d
    return time_mix + channel_mix


def _mamba_flops_per_token(cfg: ArchConfig) -> float:
    d = cfg.d_model
    d_inner = 2 * d
    H = d_inner // cfg.mamba_head_dim
    N = cfg.ssm_state
    proj = 2 * d * (2 * d_inner + 2 * N + H) + 2 * d_inner * d
    conv = 2 * cfg.conv_kernel * d_inner
    scan = 6 * d_inner * N
    return proj + conv + scan


def forward_flops(cfg: ArchConfig, seq_len: int, ctx: float | None = None,
                  batch: int = 1) -> float:
    """Analytic forward flops for `batch` sequences of `seq_len` tokens.

    ctx: average attention context per token (defaults to causal S/2,
    capped by the sliding window if set)."""
    tokens = batch * seq_len
    if ctx is None:
        ctx = seq_len / 2.0
        if cfg.sliding_window:
            ctx = min(ctx, float(cfg.sliding_window))
    per_tok = 0.0
    for kind in (["rwkv6"] * cfg.num_layers if cfg.attn_free else
                 ["mamba2"] * cfg.num_layers if cfg.shared_attn_every else
                 ["attn"] * cfg.num_layers):
        if kind == "attn":
            per_tok += _attn_flops_per_token(cfg, ctx) \
                + _ffn_flops_per_token(cfg)
        elif kind == "rwkv6":
            per_tok += _rwkv_flops_per_token(cfg)
        elif kind == "mamba2":
            per_tok += _mamba_flops_per_token(cfg)
    if cfg.shared_attn_every:    # zamba2 shared attention invocations
        n_inv = len(cfg.shared_attn_positions())
        per_tok += n_inv * (_attn_flops_per_token(cfg, ctx)
                            + 6 * cfg.d_model * cfg.d_ff
                            + 2 * cfg.d_model * cfg.d_model)
    if cfg.encoder_decoder:
        # encoder (full attn over frames) + decoder cross-attention
        F = cfg.encoder_frames
        enc_per_frame = _attn_flops_per_token(cfg, F) \
            + _ffn_flops_per_token(cfg)
        enc = cfg.encoder_layers * enc_per_frame * batch * F
        cross_per_tok = 2 * cfg.d_model * cfg.q_dim * 2 \
            + 4 * F * cfg.num_heads * cfg.resolved_head_dim
        per_tok += cfg.num_layers * cross_per_tok
        return enc + tokens * (per_tok + 2 * cfg.d_model
                               * cfg.padded_vocab)
    per_tok += 2 * cfg.d_model * cfg.padded_vocab      # logits
    return tokens * per_tok


def flops_estimate(cfg: ArchConfig, shape: InputShape) -> float:
    """Analytic flops of the lowered step (global, all chips)."""
    if shape.kind == "train":
        return 3.0 * forward_flops(cfg, shape.seq_len,
                                   batch=shape.global_batch)
    if shape.kind == "prefill":
        return forward_flops(cfg, shape.seq_len, batch=shape.global_batch)
    # decode: 1 token, full-context attention reads
    ctx = float(shape.seq_len)
    if cfg.sliding_window:
        ctx = min(ctx, float(cfg.sliding_window))
    return forward_flops(cfg, 1, ctx=ctx, batch=shape.global_batch)


def model_flops_convention(cfg: ArchConfig, shape: InputShape,
                           n_params_active: int) -> float:
    """The brief's MODEL_FLOPS: 6·N·D (train) / 2·N·D (inference),
    N = active params, D = tokens processed."""
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_params_active * tokens


def affine_correct(cost_small: float, cost_large: float, l_small: int,
                   l_large: int, l_full: int) -> float:
    """cost(L) = a + L·b fitted at two unrolled depths."""
    b = (cost_large - cost_small) / (l_large - l_small)
    a = cost_small - l_small * b
    return a + l_full * b


def reduced_depth(cfg: ArchConfig, layers: int) -> ArchConfig:
    """Same width, reduced depth (for the unrolled accounting compiles).

    shared_attn_every is preserved so the zamba2 shared-block-per-layer
    ratio matches the full model (use depth pairs that are multiples of
    shared_attn_every)."""
    repl = {"num_layers": layers}
    if cfg.encoder_decoder:
        repl["encoder_layers"] = layers
    return dataclasses.replace(cfg, **repl)


def depth_pair(cfg: ArchConfig) -> tuple[int, int]:
    if cfg.shared_attn_every:
        return cfg.shared_attn_every, 2 * cfg.shared_attn_every
    return 2, 4
